//! Any-precision nested weight store (after *Any-Precision LLM*, see
//! PAPERS.md): one memory-resident artifact serving every bit-width.
//!
//! A [`BitPlaneStore`] decomposes a parent `max_bits`-bit [`LutLayer`]'s
//! codes into per-bit planes — plane `p` holds bit `p` of every code,
//! packed bitwise (`ceil(n/8)` bytes per row, LSB-first within a byte) —
//! plus one per-row codebook *per served width*. Reading only the top
//! `w` planes reconstructs a valid `w`-bit model: the `w`-bit code is
//! exactly `parent_code >> (max_bits - w)`, so the 2- and 3-bit models
//! are prefix-slices of the 4-bit codes and cost no extra code storage.
//! Resident memory is therefore max(width) planes + the (tiny) sum of
//! per-width codebooks — not sum(widths) of independently packed models.
//!
//! The per-width codebooks come from a seedless upgrade path off the
//! GANQ solver's `max_bits` solution: dropping the LSB merges the two
//! children `2c` / `2c+1` of each surviving code `c`, so the `w`-bit
//! codebook is initialized by count-weighted child merging and then
//! re-fitted against the calibration Gram already produced for the
//! parent solve ([`BitPlaneStore::derive`] runs one exact
//! [`ganq::tstep`] per width on the preconditioned H). Without
//! calibration stats ([`BitPlaneStore::nest`]) the count-weighted merge
//! *is* the identity-Hessian optimum w.r.t. the parent reconstruction
//! (bucket means of the parent's dequantized values), matching the
//! H = I degeneration documented on [`ganq::fit_codebook_identity`].
//!
//! Serving reads the planes without materializing per-width packed
//! copies: `quant::kernels::lut_gemm_planes_into` streams the top `w`
//! planes straight into the bucket-lane mpGEMM, and
//! `PackedLut::from_planes` materializes a standalone packed form
//! (byte-identical to packing the slice) for parity tests and the AOT
//! export path.

use std::collections::BTreeMap;

use crate::tensor::{linalg, Mat};
use crate::util::pool;

use super::ganq;
use super::lut::{lut_from_parts, LutLayer};
use super::Storage;

/// Nested bit-plane weight store: parent codes as per-bit planes plus a
/// codebook per served width. See the module docs for the layout.
#[derive(Debug, Clone)]
pub struct BitPlaneStore {
    pub m: usize,
    pub n: usize,
    /// parent (maximum served) code width
    pub max_bits: u8,
    /// `planes[p]` holds bit `p` (0 = LSB) of every code, row-major with
    /// `ceil(n/8)` bytes per row; column `j` sits at byte `j/8`, bit
    /// `j%8` (LSB-first)
    pub planes: Vec<Vec<u8>>,
    /// per-row codebooks keyed by width: `codebooks[&w]` is `[m, 2^w]`.
    /// The `max_bits` entry is the parent solver's codebook verbatim.
    pub codebooks: BTreeMap<u8, Mat>,
}

/// Bytes per plane row for `n` columns.
#[inline]
pub fn plane_row_bytes(n: usize) -> usize {
    n.div_ceil(8)
}

/// Decompose flat `[m * n]` codes into `bits` bit-planes.
fn pack_planes(codes: &[u8], m: usize, n: usize, bits: u8) -> Vec<Vec<u8>> {
    let rowb = plane_row_bytes(n);
    let mut planes = vec![vec![0u8; m * rowb]; bits as usize];
    for i in 0..m {
        for j in 0..n {
            let c = codes[i * n + j];
            debug_assert!((c as usize) < (1usize << bits));
            for (p, plane) in planes.iter_mut().enumerate() {
                plane[i * rowb + j / 8] |= ((c >> p) & 1) << (j % 8);
            }
        }
    }
    planes
}

/// One merge level of the upgrade path: the `w`-bit init codebook from
/// the `(w+1)`-bit one. The two children `2c` / `2c+1` of each surviving
/// code are paired, weighted by their bucket counts so the merged
/// centroid is the bucket mean of the children's reconstruction; a pair
/// with no assigned codes falls back to the plain midpoint.
fn merge_level(t: &Mat, counts: &[usize]) -> Mat {
    let m = t.rows;
    let k2 = t.cols;
    let k = k2 / 2;
    let mut out = Mat::zeros(m, k);
    for i in 0..m {
        let tr = t.row(i);
        let cr = &counts[i * k2..(i + 1) * k2];
        let orow = out.row_mut(i);
        for c in 0..k {
            let (n0, n1) = (cr[2 * c] as f32, cr[2 * c + 1] as f32);
            orow[c] = if n0 + n1 > 0.0 {
                (n0 * tr[2 * c] + n1 * tr[2 * c + 1]) / (n0 + n1)
            } else {
                0.5 * (tr[2 * c] + tr[2 * c + 1])
            };
        }
    }
    out
}

fn build(
    parent: &LutLayer,
    widths: &[u8],
    refit: Option<(&Mat, &Mat)>,
) -> BitPlaneStore {
    assert!(!widths.is_empty(), "need at least one width");
    let mut ws: Vec<u8> = widths.to_vec();
    ws.sort_unstable();
    ws.dedup();
    assert!(ws[0] >= 1, "width 0 is not servable");
    assert_eq!(
        *ws.last().expect("nonempty"),
        parent.bits,
        "max width must equal the parent's bits"
    );
    let (m, n) = (parent.m, parent.n);
    let planes = pack_planes(&parent.codes, m, n, parent.bits);
    let mut codebooks = BTreeMap::new();
    codebooks.insert(parent.bits, parent.codebook.clone());
    // preconditioned Gram for the exact per-width T-step refit (same
    // regularization the parent GANQ solve used)
    let hp = refit.map(|(_, h)| linalg::precondition(h));
    let mut t = parent.codebook.clone();
    for wd in (ws[0]..parent.bits).rev() {
        // bucket counts at width wd+1 drive the count-weighted merge
        let shift = parent.bits - (wd + 1);
        let k2 = 1usize << (wd + 1);
        let mut counts = vec![0usize; m * k2];
        for i in 0..m {
            for j in 0..n {
                let c = (parent.codes[i * n + j] >> shift) as usize;
                counts[i * k2 + c] += 1;
            }
        }
        t = merge_level(&t, &counts);
        if ws.contains(&wd) {
            if let (Some((w_mat, _)), Some(hp)) = (refit, hp.as_ref()) {
                // one T-step is the exact per-row solve given the sliced
                // codes; empty buckets keep the merged init
                let codes_w: Vec<u8> = parent
                    .codes
                    .iter()
                    .map(|&c| c >> (parent.bits - wd))
                    .collect();
                let threads = pool::threads_for(m * n * (1usize << wd));
                t = ganq::tstep(w_mat, hp, &codes_w, &t, threads);
            }
            codebooks.insert(wd, t.clone());
        }
    }
    BitPlaneStore { m, n, max_bits: parent.bits, planes, codebooks }
}

/// Nested vs standalone storage accounting (the double-counting fix:
/// shared planes are charged once, only codebooks repeat per width).
#[derive(Debug, Clone)]
pub struct StorageReport {
    /// the one resident artifact: max-width planes + every codebook
    pub nested: Storage,
    /// what each width would cost as an independent [`LutLayer`]
    pub standalone: Vec<(u8, Storage)>,
}

impl StorageReport {
    /// Sum-of-widths bits if every width were packed independently.
    pub fn standalone_total_bits(&self) -> usize {
        self.standalone.iter().map(|(_, s)| s.total_bits()).sum()
    }
}

impl BitPlaneStore {
    /// Nest a parent LUT layer without calibration statistics: per-width
    /// codebooks are count-weighted child merges (= bucket means of the
    /// parent's dequantized values, the identity-Hessian optimum).
    pub fn nest(parent: &LutLayer, widths: &[u8]) -> BitPlaneStore {
        build(parent, widths, None)
    }

    /// The seedless upgrade path: nest a parent GANQ solution and re-fit
    /// each narrower codebook against the layer's weights `w` and
    /// calibration Gram `h` (one exact [`ganq::tstep`] per width on the
    /// preconditioned H — the stats the parent solve already produced).
    pub fn derive(
        parent: &LutLayer,
        w: &Mat,
        h: &Mat,
        widths: &[u8],
    ) -> BitPlaneStore {
        build(parent, widths, Some((w, h)))
    }

    /// Widths this store can serve, ascending.
    pub fn widths(&self) -> Vec<u8> {
        self.codebooks.keys().copied().collect()
    }

    /// Bit `p` of the code at `(i, j)` read from its plane.
    #[inline]
    pub fn bit(&self, p: usize, i: usize, j: usize) -> u8 {
        let rowb = plane_row_bytes(self.n);
        (self.planes[p][i * rowb + j / 8] >> (j % 8)) & 1
    }

    /// Full-width (parent) code at `(i, j)`.
    pub fn code(&self, i: usize, j: usize) -> u8 {
        self.code_at(i, j, self.max_bits)
    }

    /// `w`-bit code at `(i, j)`: the top `w` planes, i.e.
    /// `parent_code >> (max_bits - w)`.
    #[inline]
    pub fn code_at(&self, i: usize, j: usize, w: u8) -> u8 {
        let shift = (self.max_bits - w) as usize;
        let mut c = 0u8;
        for b in 0..w as usize {
            c |= self.bit(b + shift, i, j) << b;
        }
        c
    }

    /// Materialize the standalone `w`-bit [`LutLayer`] (codes are the
    /// top-`w` plane slice, codebook the fitted per-width one). Used for
    /// parity tests, perplexity evaluation, and the AOT export path; the
    /// native serving kernel streams the planes directly instead.
    pub fn slice(&self, w: u8) -> LutLayer {
        let t = self
            .codebooks
            .get(&w)
            .unwrap_or_else(|| panic!("width {} not in store", w));
        let mut codes = vec![0u8; self.m * self.n];
        for i in 0..self.m {
            for j in 0..self.n {
                codes[i * self.n + j] = self.code_at(i, j, w);
            }
        }
        lut_from_parts(self.m, self.n, w, codes, t.clone())
    }

    /// Nested storage accounting: the planes are charged **once** at
    /// `max_bits` per code (they are shared by every width); only the
    /// fp16 codebooks repeat per width family.
    pub fn storage(&self) -> Storage {
        Storage {
            code_bits: self.m * self.n * self.max_bits as usize,
            meta_bits: self
                .codebooks
                .keys()
                .map(|&w| self.m * (1usize << w) * 16)
                .sum(),
            sparse_bits: 0,
        }
    }

    /// Nested vs per-width-standalone storage.
    pub fn storage_report(&self) -> StorageReport {
        StorageReport {
            nested: self.storage(),
            standalone: self
                .widths()
                .iter()
                .map(|&w| (w, self.slice(w).storage()))
                .collect(),
        }
    }

    /// Resident bytes of the one in-memory artifact: every plane plus
    /// every per-width f32 codebook.
    pub fn resident_bytes(&self) -> usize {
        let planes: usize = self.planes.iter().map(|p| p.len()).sum();
        let books: usize = self
            .codebooks
            .keys()
            .map(|&w| self.m * (1usize << w) * 4)
            .sum();
        planes + books
    }

    /// Weight bytes that stream per decode step at width `w`: only the
    /// top `w` planes plus that width's codebook (narrower widths read
    /// strictly less memory — the degradation win).
    pub fn bytes_per_decode(&self, w: u8) -> usize {
        self.m * plane_row_bytes(self.n) * w as usize
            + self.m * (1usize << w) * 4
    }

    /// Dense reconstruction at the maximum width.
    pub fn dequant_max(&self) -> Mat {
        self.slice(self.max_bits).dequant()
    }

    /// What a decode step at width `w` streams relative to the full
    /// max-width stream — the per-draft-token cost of self-speculative
    /// decoding, where the drafter is the `w`-bit view of this store
    /// and the verifier the max-width view. Well under `w / max_bits`
    /// for wide layers, since narrow codebooks also shrink.
    pub fn draft_cost_frac(&self, w: u8) -> f64 {
        self.bytes_per_decode(w) as f64
            / self.bytes_per_decode(self.max_bits) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn random_parent(
        rng: &mut Rng,
        m: usize,
        n: usize,
        bits: u8,
    ) -> LutLayer {
        let k = 1usize << bits;
        let codes = (0..m * n).map(|_| rng.below(k as u64) as u8).collect();
        // sorted codebook rows so merges look like real quantizer output
        let mut cb = Mat::zeros(m, k);
        for i in 0..m {
            let mut row = rng.normal_vec_f32(k);
            row.sort_by(|a, b| a.partial_cmp(b).unwrap());
            cb.row_mut(i).copy_from_slice(&row);
        }
        lut_from_parts(m, n, bits, codes, cb)
    }

    #[test]
    fn plane_roundtrip_recovers_parent_codes() {
        prop::check("anyprec_planes", 51, 16, |rng, case| {
            let m = 1 + rng.below(6) as usize;
            // force ragged (non-multiple-of-8) n on half the cases
            let mut n = 1 + rng.below(40) as usize;
            if case % 2 == 0 && n % 8 == 0 {
                n += 3;
            }
            let bits = if rng.below(2) == 0 { 3 } else { 4 };
            let parent = random_parent(rng, m, n, bits);
            let store = BitPlaneStore::nest(&parent, &[bits]);
            for i in 0..m {
                for j in 0..n {
                    crate::prop_assert!(
                        store.code(i, j) == parent.code(i, j),
                        "code mismatch at ({}, {})",
                        i,
                        j
                    );
                }
            }
            Ok(())
        });
    }

    #[test]
    fn draft_cost_frac_tracks_decode_bytes() {
        let mut rng = Rng::new(55);
        let parent = random_parent(&mut rng, 64, 256, 4);
        let store = BitPlaneStore::nest(&parent, &[2, 3, 4]);
        assert_eq!(store.draft_cost_frac(4), 1.0);
        let f2 = store.draft_cost_frac(2);
        let f3 = store.draft_cost_frac(3);
        assert!(f2 < f3 && f3 < 1.0, "f2={} f3={}", f2, f3);
        // narrow drafts undercut the naive w/max ratio: planes shrink
        // linearly, but the 2^w codebook shrinks much faster
        assert!(f2 < 0.5, "2-bit draft should stream <half: {}", f2);
    }

    #[test]
    fn max_width_slice_is_parent_verbatim() {
        let mut rng = Rng::new(52);
        let parent = random_parent(&mut rng, 5, 19, 4);
        let store = BitPlaneStore::nest(&parent, &[2, 3, 4]);
        let s4 = store.slice(4);
        assert_eq!(s4.codes, parent.codes);
        assert_eq!(s4.codebook.data, parent.codebook.data);
        assert_eq!(store.widths(), vec![2, 3, 4]);
    }

    #[test]
    fn slice_codes_are_top_bits_of_parent() {
        prop::check("anyprec_slice", 53, 12, |rng, _| {
            let m = 1 + rng.below(5) as usize;
            let n = 1 + rng.below(33) as usize;
            let parent = random_parent(rng, m, n, 4);
            let store = BitPlaneStore::nest(&parent, &[2, 3, 4]);
            for w in [2u8, 3, 4] {
                let s = store.slice(w);
                for (c, &pc) in s.codes.iter().zip(&parent.codes) {
                    crate::prop_assert!(
                        *c == pc >> (4 - w),
                        "width {} code {} != parent {} >> {}",
                        w,
                        c,
                        pc,
                        4 - w
                    );
                }
            }
            Ok(())
        });
    }

    #[test]
    fn slice_matmul_matches_standalone_layer_bitwise() {
        // a slice must behave exactly like a standalone LutLayer built
        // from the same codes + codebook — including the mpGEMM output
        prop::check("anyprec_matmul", 54, 8, |rng, _| {
            let m = 1 + rng.below(16) as usize;
            let n = 1 + rng.below(24) as usize;
            let p = 1 + rng.below(5) as usize;
            let parent = random_parent(rng, m, n, 4);
            let store = BitPlaneStore::nest(&parent, &[2, 3, 4]);
            let x = Mat::from_vec(p, n, rng.normal_vec_f32(p * n));
            for w in [2u8, 3, 4] {
                let s = store.slice(w);
                let standalone = lut_from_parts(
                    m,
                    n,
                    w,
                    s.codes.clone(),
                    s.codebook.clone(),
                );
                let a = s.lut_matmul(&x);
                let b = standalone.lut_matmul(&x);
                crate::prop_assert!(
                    a.data == b.data,
                    "width {} matmul not bitwise-identical",
                    w
                );
            }
            Ok(())
        });
    }

    #[test]
    fn merge_is_count_weighted_bucket_mean() {
        // 1 row, 2-bit parent, codes [0, 0, 1, 3]:
        //   width-1 bucket 0 <- children {0 (x2), 1 (x1)} = (2*t0+t1)/3
        //   width-1 bucket 1 <- children {2 (x0), 3 (x1)} = t3
        let parent = lut_from_parts(
            1,
            4,
            2,
            vec![0, 0, 1, 3],
            Mat::from_vec(1, 4, vec![0.0, 1.0, 2.0, 3.0]),
        );
        let store = BitPlaneStore::nest(&parent, &[1, 2]);
        let t1 = &store.codebooks[&1];
        assert!((t1[(0, 0)] - 1.0 / 3.0).abs() < 1e-6, "{}", t1[(0, 0)]);
        assert!((t1[(0, 1)] - 3.0).abs() < 1e-6, "{}", t1[(0, 1)]);
    }

    #[test]
    fn nest_equals_identity_bucket_means_of_parent_dequant() {
        let mut rng = Rng::new(55);
        let parent = random_parent(&mut rng, 4, 30, 4);
        let store = BitPlaneStore::nest(&parent, &[2, 4]);
        let deq = parent.dequant();
        let s2 = store.slice(2);
        for i in 0..4 {
            for c in 0..4u8 {
                let vals: Vec<f32> = (0..30)
                    .filter(|&j| s2.code(i, j) == c)
                    .map(|j| deq[(i, j)])
                    .collect();
                if vals.is_empty() {
                    continue;
                }
                let mean = vals.iter().sum::<f32>() / vals.len() as f32;
                assert!(
                    (s2.codebook[(i, c as usize)] - mean).abs() < 1e-4,
                    "row {} bucket {}: {} vs {}",
                    i,
                    c,
                    s2.codebook[(i, c as usize)],
                    mean
                );
            }
        }
    }

    #[test]
    fn derive_refit_no_worse_than_plain_merge() {
        // Gram-refit codebooks must not lose to the calibration-free
        // merge on the layer-wise objective tr(D H D^T)
        let mut rng = Rng::new(56);
        let (m, n, p) = (6, 24, 48);
        let w = Mat::from_vec(m, n, rng.normal_vec_f32(m * n));
        let x = Mat::from_vec(p, n, rng.normal_vec_f32(p * n));
        let h = x.t().matmul(&x);
        let sol = ganq::solve(&w, &h, 4, 4, ganq::Precond::Adaptive, false);
        let parent =
            lut_from_parts(m, n, 4, sol.codes.clone(), sol.codebook.clone());
        let nested = BitPlaneStore::nest(&parent, &[2, 3, 4]);
        let derived = BitPlaneStore::derive(&parent, &w, &h, &[2, 3, 4]);
        for wd in [2u8, 3] {
            let e_nest = linalg::layer_error(
                &w,
                &nested.slice(wd).dequant(),
                &h,
            );
            let e_drv = linalg::layer_error(
                &w,
                &derived.slice(wd).dequant(),
                &h,
            );
            assert!(
                e_drv <= e_nest * 1.0001 + 1e-9,
                "width {}: refit {} worse than merge {}",
                wd,
                e_drv,
                e_nest
            );
        }
    }

    #[test]
    fn storage_report_pins_nested_accounting() {
        // nested total = max-width planes (counted once) + sum of
        // per-width codebooks — strictly below sum-of-standalone
        let mut rng = Rng::new(57);
        let (m, n) = (32, 96);
        let parent = random_parent(&mut rng, m, n, 4);
        let store = BitPlaneStore::nest(&parent, &[2, 3, 4]);
        let rep = store.storage_report();
        let expect_code = m * n * 4;
        let expect_meta = m * (4 + 8 + 16) * 16;
        assert_eq!(rep.nested.code_bits, expect_code);
        assert_eq!(rep.nested.meta_bits, expect_meta);
        assert_eq!(rep.nested.total_bits(), expect_code + expect_meta);
        assert!(
            rep.nested.total_bits() < rep.standalone_total_bits(),
            "nested {} !< standalone {}",
            rep.nested.total_bits(),
            rep.standalone_total_bits()
        );
        // and the resident artifact is ~ the 4-bit model alone, not 2+3+4
        let lut4_bytes = store.slice(4).bytes_per_decode();
        assert!(
            store.resident_bytes() < 2 * lut4_bytes,
            "resident {} vs lut4 {}",
            store.resident_bytes(),
            lut4_bytes
        );
    }

    #[test]
    fn narrower_widths_stream_less_memory() {
        let mut rng = Rng::new(58);
        let parent = random_parent(&mut rng, 64, 256, 4);
        let store = BitPlaneStore::nest(&parent, &[2, 3, 4]);
        assert!(store.bytes_per_decode(2) < store.bytes_per_decode(3));
        assert!(store.bytes_per_decode(3) < store.bytes_per_decode(4));
    }

    #[test]
    #[should_panic(expected = "max width must equal")]
    fn widths_must_include_parent_bits() {
        let mut rng = Rng::new(59);
        let parent = random_parent(&mut rng, 2, 8, 4);
        let _ = BitPlaneStore::nest(&parent, &[2, 3]);
    }
}
