//! GPTQ baseline (Frantar et al., 2022): per-row uniform quantization with
//! optimal-brain-surgeon error compensation. Columns are processed in
//! order; after rounding column j, the remaining columns are updated with
//! the weighted error via the upper Cholesky factor of H^{-1}.
//!
//! Matches the reference implementation's structure (act-order off,
//! dampening via the same diagonal-dominance preconditioning GANQ uses so
//! the two baselines see identical H conditioning).

use crate::tensor::{linalg, Mat};
use crate::util::pool;

use super::{
    dequant_code, uniform_quant_segment, QuantResult, Quantizer, Storage,
};

#[derive(Debug, Clone)]
pub struct Gptq {
    pub bits: u8,
    pub group: Option<usize>,
}

impl Gptq {
    pub fn new(bits: u8) -> Self {
        Gptq { bits, group: None }
    }

    pub fn grouped(bits: u8, group: usize) -> Self {
        Gptq { bits, group: Some(group) }
    }
}

/// Invert an SPD matrix via its Cholesky factor (column-by-column solves).
fn spd_inverse(a: &Mat) -> Option<Mat> {
    let n = a.rows;
    let l = linalg::cholesky(a)?;
    let mut inv = Mat::zeros(n, n);
    for j in 0..n {
        let mut e = vec![0.0f64; n];
        e[j] = 1.0;
        let y = linalg::solve_lower(&l, &e);
        let x = linalg::solve_lower_t(&l, &y);
        for i in 0..n {
            inv[(i, j)] = x[i] as f32;
        }
    }
    Some(inv)
}

/// Upper-triangular Cholesky factor U with A = U^T U.
fn cholesky_upper(a: &Mat) -> Option<Mat> {
    // A = L L^T  =>  U = L^T
    linalg::cholesky(a).map(|l| l.t())
}

impl Quantizer for Gptq {
    fn name(&self) -> String {
        match self.group {
            Some(g) => format!("gptq-g{}", g),
            None => "gptq".to_string(),
        }
    }

    fn quantize(&self, w: &Mat, h: &Mat) -> QuantResult {
        let (m, n) = (w.rows, w.cols);
        let hp = linalg::precondition(h);
        let hinv = spd_inverse(&hp).expect("preconditioned H is SPD");
        let u = cholesky_upper(&hinv).expect("H^-1 SPD");
        let g = self.group.unwrap_or(n).min(n);
        let bits = self.bits;
        let levels = ((1u32 << bits) - 1) as f32;

        let mut w_hat = Mat::zeros(m, n);
        // copy W (mutated in place by compensation)
        w_hat.data.copy_from_slice(&w.data);
        let threads = pool::default_threads();
        let udiag: Vec<f32> = (0..n).map(|j| u[(j, j)]).collect();
        pool::par_rows_mut(&mut w_hat.data, n, threads, |_row0, chunk| {
            for wrow in chunk.chunks_mut(n) {
                let mut scale = 1.0f32;
                let mut zero = 0.0f32;
                for j in 0..n {
                    if j % g == 0 {
                        // (re)fit the uniform grid on the *current*
                        // (compensated) group values, as GPTQ does
                        let (_c, s, z) =
                            uniform_quant_segment(&wrow[j..(j + g).min(n)], bits);
                        scale = s;
                        zero = z;
                    }
                    let wj = wrow[j];
                    let c = ((wj / scale).round() + zero).clamp(0.0, levels)
                        as u8;
                    let qj = dequant_code(c, scale, zero);
                    wrow[j] = qj;
                    let err = (wj - qj) / udiag[j];
                    if err != 0.0 {
                        let urow = u.row(j);
                        for jj in j + 1..n {
                            wrow[jj] -= err * urow[jj];
                        }
                    }
                }
            }
        });

        let groups = n.div_ceil(g);
        let storage = Storage {
            code_bits: m * n * bits as usize,
            meta_bits: m * groups * 2 * 16,
            sparse_bits: 0,
        };
        QuantResult {
            method: self.name(),
            bits,
            w_hat,
            lut: None,
            sparse: None,
            storage,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::rtn::Rtn;
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn problem(rng: &mut Rng, m: usize, n: usize, p: usize) -> (Mat, Mat) {
        let w = Mat::from_vec(m, n, rng.normal_vec_f32(m * n));
        let x = Mat::from_vec(n, p, rng.normal_vec_f32(n * p));
        (w, x.gram())
    }

    #[test]
    fn spd_inverse_correct() {
        let mut rng = Rng::new(61);
        let x = Mat::from_vec(8, 20, rng.normal_vec_f32(160));
        let a = linalg::precondition(&x.gram());
        let inv = spd_inverse(&a).unwrap();
        let prod = a.matmul(&inv);
        let eye = Mat::eye(8);
        assert!(
            prop::all_close(&prod.data, &eye.data, 5e-3, 5e-3),
            "maxdiff {}",
            prop::max_abs_diff(&prod.data, &eye.data)
        );
    }

    #[test]
    fn beats_rtn_with_correlated_activations() {
        // GPTQ's whole point: with a non-identity H, compensation wins
        prop::check("gptq_beats_rtn", 62, 6, |rng, _| {
            let (w, h) = problem(rng, 16, 32, 48);
            let e_gptq = Gptq::new(3).quantize(&w, &h).layer_error(&w, &h);
            let e_rtn = Rtn::new(3).quantize(&w, &h).layer_error(&w, &h);
            crate::prop_assert!(
                e_gptq < e_rtn,
                "gptq {} !< rtn {}",
                e_gptq,
                e_rtn
            );
            Ok(())
        });
    }

    #[test]
    fn grouped_variant_runs_and_helps_vs_rtn_grouped() {
        let mut rng = Rng::new(63);
        let (w, h) = problem(&mut rng, 16, 64, 96);
        let e_gptq =
            Gptq::grouped(3, 16).quantize(&w, &h).layer_error(&w, &h);
        let e_rtn = Rtn::grouped(3, 16).quantize(&w, &h).layer_error(&w, &h);
        assert!(e_gptq < e_rtn * 1.05, "{} vs {}", e_gptq, e_rtn);
    }

    #[test]
    fn output_values_on_uniform_grid() {
        // every produced weight must be representable: (c - z) * s for the
        // group's grid (we verify via nearest-grid reconstruction residual
        // being ~0 relative to grid step)
        let mut rng = Rng::new(64);
        let (w, h) = problem(&mut rng, 4, 16, 32);
        let r = Gptq::new(4).quantize(&w, &h);
        assert!(r.w_hat.data.iter().all(|v| v.is_finite()));
    }
}
