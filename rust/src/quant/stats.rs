//! Weight-distribution statistics — regenerates Figure 1(b) (violin plots
//! of decoder weights showing non-uniformity) as quantile/moment summaries
//! printable in a terminal.

use crate::tensor::Mat;

#[derive(Debug, Clone)]
pub struct DistStats {
    pub name: String,
    pub min: f32,
    pub max: f32,
    pub mean: f64,
    pub std: f64,
    /// excess kurtosis: 0 for a gaussian, > 0 = heavy tails (the paper's
    /// argument for non-uniform quantization)
    pub kurtosis: f64,
    /// quantiles at 0.1%, 1%, 25%, 50%, 75%, 99%, 99.9%
    pub quantiles: [f32; 7],
    /// fraction of range occupied by the central 99% of mass — tiny values
    /// mean uniform grids waste most of their levels on tails
    pub central99_range_frac: f64,
}

pub const QUANTILE_PROBS: [f64; 7] =
    [0.001, 0.01, 0.25, 0.5, 0.75, 0.99, 0.999];

pub fn dist_stats(name: &str, w: &Mat) -> DistStats {
    let mut v: Vec<f32> = w.data.clone();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = v.len();
    let mean = v.iter().map(|&x| x as f64).sum::<f64>() / n as f64;
    let m2 = v
        .iter()
        .map(|&x| (x as f64 - mean).powi(2))
        .sum::<f64>()
        / n as f64;
    let m4 = v
        .iter()
        .map(|&x| (x as f64 - mean).powi(4))
        .sum::<f64>()
        / n as f64;
    let std = m2.sqrt();
    let kurtosis = if m2 > 0.0 { m4 / (m2 * m2) - 3.0 } else { 0.0 };
    let q = |p: f64| v[((n - 1) as f64 * p).round() as usize];
    let quantiles = [
        q(0.001),
        q(0.01),
        q(0.25),
        q(0.5),
        q(0.75),
        q(0.99),
        q(0.999),
    ];
    let full = (v[n - 1] - v[0]) as f64;
    let central = (q(0.995) - q(0.005)) as f64;
    DistStats {
        name: name.to_string(),
        min: v[0],
        max: v[n - 1],
        mean,
        std,
        kurtosis,
        quantiles,
        central99_range_frac: if full > 0.0 { central / full } else { 1.0 },
    }
}

/// ASCII "violin": a histogram strip over the value range.
pub fn ascii_violin(w: &Mat, bins: usize, width: usize) -> String {
    let mut lo = f32::INFINITY;
    let mut hi = f32::NEG_INFINITY;
    for &v in &w.data {
        lo = lo.min(v);
        hi = hi.max(v);
    }
    let span = (hi - lo).max(1e-12);
    let mut hist = vec![0usize; bins];
    for &v in &w.data {
        let b = (((v - lo) / span) * bins as f32) as usize;
        hist[b.min(bins - 1)] += 1;
    }
    let mx = *hist.iter().max().unwrap_or(&1) as f64;
    let mut out = String::new();
    for (bi, &c) in hist.iter().enumerate() {
        let x = lo + span * (bi as f32 + 0.5) / bins as f32;
        let bar = ((c as f64 / mx) * width as f64).round() as usize;
        out.push_str(&format!(
            "{:>9.4} |{}\n",
            x,
            "#".repeat(bar)
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn gaussian_has_near_zero_kurtosis() {
        let mut rng = Rng::new(1);
        let w = Mat::from_vec(64, 64, rng.normal_vec_f32(64 * 64));
        let s = dist_stats("g", &w);
        assert!(s.kurtosis.abs() < 0.3, "{}", s.kurtosis);
        assert!(s.mean.abs() < 0.05);
        assert!((s.std - 1.0).abs() < 0.05);
    }

    #[test]
    fn heavy_tails_detected() {
        let mut rng = Rng::new(2);
        let mut data = rng.normal_vec_f32(4000);
        for i in 0..10 {
            data[i] = 25.0; // outliers (0.25% — outside the central 99%)
        }
        let w = Mat::from_vec(40, 100, data);
        let s = dist_stats("t", &w);
        assert!(s.kurtosis > 5.0, "{}", s.kurtosis);
        assert!(s.central99_range_frac < 0.5, "{}", s.central99_range_frac);
    }

    #[test]
    fn quantiles_monotone() {
        let mut rng = Rng::new(3);
        let w = Mat::from_vec(10, 50, rng.normal_vec_f32(500));
        let s = dist_stats("q", &w);
        for win in s.quantiles.windows(2) {
            assert!(win[0] <= win[1]);
        }
        assert!(s.min <= s.quantiles[0] && s.quantiles[6] <= s.max);
    }

    #[test]
    fn violin_renders() {
        let mut rng = Rng::new(4);
        let w = Mat::from_vec(8, 32, rng.normal_vec_f32(256));
        let v = ascii_violin(&w, 11, 30);
        assert_eq!(v.lines().count(), 11);
    }
}
