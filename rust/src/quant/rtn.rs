//! RTN: round-to-nearest per-channel uniform quantization (the basic
//! baseline of §1) with optional group-wise variant (g128, Table 5).
//! The per-channel (ungrouped) form is also expressible as a LUT with a
//! uniform-grid codebook — which is exactly GANQ's T^0 initialization.

use crate::tensor::Mat;

use super::{
    dequant_code, lut::lut_from_parts, uniform_quant_segment, QuantResult,
    Quantizer, Storage,
};

#[derive(Debug, Clone)]
pub struct Rtn {
    pub bits: u8,
    pub group: Option<usize>,
}

impl Rtn {
    pub fn new(bits: u8) -> Self {
        Rtn { bits, group: None }
    }

    pub fn grouped(bits: u8, group: usize) -> Self {
        Rtn { bits, group: Some(group) }
    }
}

/// Uniform-grid codebook for one row (RTN-as-LUT; GANQ T^0 init).
pub fn rtn_codebook_row(row: &[f32], bits: u8) -> (Vec<u8>, Vec<f32>) {
    let (codes, scale, zero) = uniform_quant_segment(row, bits);
    let k = 1usize << bits;
    let t = (0..k)
        .map(|s| dequant_code(s as u8, scale, zero))
        .collect();
    (codes, t)
}

/// Full-matrix RTN-as-LUT (per-channel): codes + uniform grid per row.
pub fn rtn_codebook(w: &Mat, bits: u8) -> (Vec<u8>, Mat) {
    let k = 1usize << bits;
    let mut codes = vec![0u8; w.rows * w.cols];
    let mut t = Mat::zeros(w.rows, k);
    for i in 0..w.rows {
        let (c, grid) = rtn_codebook_row(w.row(i), bits);
        codes[i * w.cols..(i + 1) * w.cols].copy_from_slice(&c);
        t.row_mut(i).copy_from_slice(&grid);
    }
    (codes, t)
}

impl Quantizer for Rtn {
    fn name(&self) -> String {
        match self.group {
            Some(g) => format!("rtn-g{}", g),
            None => "rtn".to_string(),
        }
    }

    fn quantize(&self, w: &Mat, _h: &Mat) -> QuantResult {
        let (m, n) = (w.rows, w.cols);
        let g = self.group.unwrap_or(n).min(n);
        let mut w_hat = Mat::zeros(m, n);
        let mut groups = 0usize;
        for i in 0..m {
            let row = w.row(i);
            let mut out = vec![0.0f32; n];
            for (gi, seg) in row.chunks(g).enumerate() {
                let (codes, scale, zero) =
                    uniform_quant_segment(seg, self.bits);
                for (jj, &c) in codes.iter().enumerate() {
                    out[gi * g + jj] = dequant_code(c, scale, zero);
                }
                if i == 0 {
                    groups = gi + 1;
                }
            }
            w_hat.row_mut(i).copy_from_slice(&out);
        }
        let lut = if self.group.is_none() && n % 2 == 0 {
            let (codes, t) = rtn_codebook(w, self.bits);
            Some(lut_from_parts(m, n, self.bits, codes, t))
        } else {
            None
        };
        let storage = Storage {
            code_bits: m * n * self.bits as usize,
            // scale + zero per group, fp16 each
            meta_bits: m * groups * 2 * 16,
            sparse_bits: 0,
        };
        QuantResult {
            method: self.name(),
            bits: self.bits,
            w_hat,
            lut,
            sparse: None,
            storage,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::linalg;
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn rand_wh(rng: &mut Rng, m: usize, n: usize) -> (Mat, Mat) {
        let w = Mat::from_vec(m, n, rng.normal_vec_f32(m * n));
        let x = Mat::from_vec(n, 2 * n, rng.normal_vec_f32(2 * n * n));
        (w, x.gram())
    }

    #[test]
    fn error_bounded_by_half_step() {
        prop::check("rtn_halfstep", 41, 10, |rng, _| {
            let (w, h) = rand_wh(rng, 4, 16);
            let r = Rtn::new(4).quantize(&w, &h);
            for i in 0..4 {
                let row = w.row(i);
                let span = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b))
                    - row.iter().fold(f32::INFINITY, |a, &b| a.min(b));
                let step = span / 15.0;
                for j in 0..16 {
                    crate::prop_assert!(
                        (w[(i, j)] - r.w_hat[(i, j)]).abs()
                            <= step * 0.5 + 1e-5,
                        "({},{})",
                        i,
                        j
                    );
                }
            }
            Ok(())
        });
    }

    #[test]
    fn lut_form_matches_dense_form() {
        let mut rng = Rng::new(42);
        let (w, h) = rand_wh(&mut rng, 6, 32);
        let r = Rtn::new(3).quantize(&w, &h);
        let lut = r.lut.as_ref().unwrap();
        assert!(prop::all_close(
            &lut.dequant().data,
            &r.w_hat.data,
            1e-6,
            1e-6
        ));
    }

    #[test]
    fn grouping_never_hurts() {
        // smaller groups adapt ranges better: g8 error <= per-row error
        prop::check("rtn_group", 43, 8, |rng, _| {
            let (w, h) = rand_wh(rng, 8, 64);
            let e_row = Rtn::new(3).quantize(&w, &h).layer_error(&w, &h);
            let e_g8 = Rtn::grouped(3, 8).quantize(&w, &h).layer_error(&w, &h);
            crate::prop_assert!(
                e_g8 <= e_row * 1.001 + 1e-9,
                "g8 {} vs row {}",
                e_g8,
                e_row
            );
            Ok(())
        });
    }

    #[test]
    fn more_bits_less_error() {
        let mut rng = Rng::new(44);
        let (w, h) = rand_wh(&mut rng, 8, 32);
        let e3 = Rtn::new(3).quantize(&w, &h).layer_error(&w, &h);
        let e4 = Rtn::new(4).quantize(&w, &h).layer_error(&w, &h);
        let e8 = Rtn::new(8).quantize(&w, &h).layer_error(&w, &h);
        assert!(e4 < e3 && e8 < e4, "{} {} {}", e3, e4, e8);
    }

    #[test]
    fn storage_per_channel_matches_table1() {
        let mut rng = Rng::new(45);
        let (w, h) = rand_wh(&mut rng, 32, 32);
        let r = Rtn::new(4).quantize(&w, &h);
        // 0.25*mn*16 bits codes + 2 fp16 per row
        assert_eq!(r.storage.code_bits, 32 * 32 * 4);
        assert_eq!(r.storage.meta_bits, 32 * 2 * 16);
        let _ = linalg::layer_error(&w, &r.w_hat, &h); // smoke
    }
}
