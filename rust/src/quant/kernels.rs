//! Batched LUT-mpGEMM kernels over **packed** code buffers — the native
//! serving hot path.
//!
//! # Packed-code layout contract (shared with `python/compile/kernels/ref.py`)
//!
//! * **Nibble container** (`bits <= 4`): byte `j` of a row holds the codes
//!   of columns `2j` (low nibble) and `2j+1` (high nibble); rows are
//!   `ceil(n/2)` bytes, an odd `n` pads the final high nibble with 0.
//!   Identical to `ref.pack_nibbles` / [`LutLayer::packed_nibbles`].
//! * **Dense 3-bit** (`bits == 3`): 8 codes -> 3 little-endian bytes per
//!   group, rows padded to a multiple of 8 codes (`ceil(n/8)*3` bytes).
//!   Identical to `ref.pack3` / [`LutLayer::packed3`]. This is the layout
//!   [`PackedLut`] uses for 3-bit weights: 3 bits/code of traffic instead
//!   of the nibble container's 4.
//!
//! # Kernel structure
//!
//! `y[p, m] = x[p, n] @ W_hat^T` without materializing `W_hat` and without
//! unpacking the codes to one byte each (the dequantization-free mpGEMM of
//! the paper, Fig. 1(a) right). Per output channel `i`:
//!
//! 1. stream the packed code row **once**, decoding two (nibble) or eight
//!    (3-bit) codes per load in-register;
//! 2. scatter-accumulate the activation columns into `K = 2^bits`
//!    per-code buckets of `p` lanes each (`buckets[c*p + pi] += x[pi, j]`)
//!    — the batch dimension is contiguous, so each code costs one
//!    `p`-wide vector add regardless of batch size: weight traffic is
//!    amortized over the whole batch;
//! 3. finish with one `K`-wide dot against the row's codebook.
//!
//! Output rows are register/cache-tiled: worker threads (sized to the
//! work by [`pool::threads_for`], so micro shapes stay on the caller's
//! thread) own disjoint `tile_m x p` tiles of `y^T`, and the `K*p` bucket
//! block stays L1-resident. The accumulation order per output element is
//! identical at every batch size and thread count — `j` ascending into
//! buckets, then `s` ascending over the codebook — so batched results are
//! bit-identical to the `p = 1` path, which the batched decode engine
//! relies on for its sequential-equivalence guarantee.

use crate::tensor::Mat;
use crate::util::pool;

use super::anyprec::BitPlaneStore;
use super::lut::LutLayer;

/// A LUT linear in packed-code form, ready for the serving hot path:
/// codes stay packed (nibble container or dense 3-bit) and are decoded
/// in-register by the mpGEMM, halving (4-bit) or ~2.7x-ing (3-bit) the
/// weight bytes streamed per token versus one-byte-per-code buffers.
#[derive(Debug, Clone)]
pub struct PackedLut {
    pub m: usize,
    pub n: usize,
    pub bits: u8,
    /// bytes per packed code row
    pub row_bytes: usize,
    /// packed codes, `m * row_bytes`
    pub codes: Vec<u8>,
    /// per-row codebook [m, 2^bits]
    pub codebook: Mat,
}

impl PackedLut {
    /// Pack a [`LutLayer`]'s codes once, ahead of serving. 3-bit layers
    /// use the dense 3-bit layout; other widths (<= 4 bits) the nibble
    /// container.
    pub fn pack(l: &LutLayer) -> PackedLut {
        assert!(
            l.bits <= 4,
            "packed serving supports <= 4-bit codes, got {}",
            l.bits
        );
        let (codes, row_bytes) = if l.bits == 3 {
            (l.packed3(), l.n.div_ceil(8) * 3)
        } else {
            (l.packed_nibbles(), l.n.div_ceil(2))
        };
        PackedLut {
            m: l.m,
            n: l.n,
            bits: l.bits,
            row_bytes,
            codes,
            codebook: l.codebook.clone(),
        }
    }

    /// Materialize the `w`-bit packed form from a nested
    /// [`BitPlaneStore`], reading only the top-`w` planes. Byte-identical
    /// to `PackedLut::pack(&store.slice(w))` — the parity contract the
    /// AOT export path and the streaming kernel both rely on. For
    /// serving, prefer [`lut_gemm_planes_into`], which skips this
    /// materialization entirely.
    pub fn from_planes(store: &BitPlaneStore, w: u8) -> PackedLut {
        PackedLut::pack(&store.slice(w))
    }

    pub fn k(&self) -> usize {
        1usize << self.bits
    }

    /// Weight bytes streamed per decode step: packed codes + f32
    /// codebooks (the memory-bound quantity of Table 6).
    pub fn bytes_per_decode(&self) -> usize {
        self.m * self.row_bytes + self.m * self.k() * 4
    }

    /// Allocating convenience wrapper around [`PackedLut::matmul_into`].
    pub fn matmul(&self, x: &Mat) -> Mat {
        let mut out = Mat::zeros(x.rows, self.m);
        let mut sc = LutScratch::new();
        self.matmul_into(x, &mut sc, &mut out);
        out
    }

    /// `out[p, m] = x[p, n] @ W_hat^T` from packed codes. `out` must
    /// already be shaped [p, m]; every element is overwritten.
    pub fn matmul_into(&self, x: &Mat, sc: &mut LutScratch, out: &mut Mat) {
        assert_eq!(x.cols, self.n, "activation width");
        let n = self.n;
        let rb = self.row_bytes;
        let codes = &self.codes;
        if self.bits == 3 {
            mpgemm_driver(&self.codebook, n, x, sc, out, |i, p, xt, bk| {
                row_buckets_pack3(&codes[i * rb..(i + 1) * rb], n, p, xt, bk);
            });
        } else {
            mpgemm_driver(&self.codebook, n, x, sc, out, |i, p, xt, bk| {
                row_buckets_nibble(&codes[i * rb..(i + 1) * rb], n, p, xt, bk);
            });
        }
    }
}

/// Reusable kernel scratch: transposed activations `x^T [n, p]` and the
/// transposed output tile `y^T [m, p]`. Owned by the decode engine's
/// per-step arena so these buffers are allocated once; the only
/// remaining per-call allocation is each worker thread's small `K*p`
/// bucket block.
#[derive(Debug, Default)]
pub struct LutScratch {
    xt: Vec<f32>,
    yt: Vec<f32>,
}

impl LutScratch {
    pub fn new() -> LutScratch {
        LutScratch::default()
    }
}

/// Unpacked-code variant (one byte per code) sharing the bucket kernel —
/// the backing implementation of [`LutLayer::lut_matmul`], kept so both
/// paths have identical accumulation order.
pub fn lut_gemm_codes_into(
    codes: &[u8],
    codebook: &Mat,
    n: usize,
    x: &Mat,
    sc: &mut LutScratch,
    out: &mut Mat,
) {
    assert_eq!(x.cols, n, "activation width");
    assert_eq!(codes.len(), codebook.rows * n, "code buffer shape");
    mpgemm_driver(codebook, n, x, sc, out, |i, p, xt, bk| {
        for (j, &c) in codes[i * n..(i + 1) * n].iter().enumerate() {
            bucket_add(bk, c as usize, p, &xt[j * p..(j + 1) * p]);
        }
    });
}

/// Any-precision variant: stream the top-`w` bit-planes of a nested
/// [`BitPlaneStore`] straight into the bucket kernel, assembling each
/// `w`-bit code in-register from one byte of each plane (8 codes per
/// gather). No per-width packed copy is ever materialized — the weight
/// bytes read per step are exactly `m * ceil(n/8) * w` plus that width's
/// codebook. Codes are consumed `j` ascending, so the output is bitwise
/// identical to [`lut_gemm_codes_into`] over `store.slice(w)` (and hence
/// to the packed paths).
pub fn lut_gemm_planes_into(
    store: &BitPlaneStore,
    w: u8,
    x: &Mat,
    sc: &mut LutScratch,
    out: &mut Mat,
) {
    assert_eq!(x.cols, store.n, "activation width");
    let codebook = store
        .codebooks
        .get(&w)
        // lint:allow(hot-panic): caller selects w from store.widths(); a miss
        // is a programming error worth a loud crash, not a recoverable state
        .unwrap_or_else(|| panic!("width {} not in store", w));
    let n = store.n;
    let rowb = n.div_ceil(8);
    let shift = (store.max_bits - w) as usize;
    let planes = &store.planes[shift..store.max_bits as usize];
    mpgemm_driver(codebook, n, x, sc, out, |i, p, xt, bk| {
        for jb in 0..rowb {
            let mut bytes = [0u8; 8];
            for (b, plane) in planes.iter().enumerate() {
                bytes[b] = plane[i * rowb + jb];
            }
            let in_group = (n - jb * 8).min(8);
            for t in 0..in_group {
                let j = jb * 8 + t;
                let mut c = 0usize;
                for (b, &byte) in bytes[..planes.len()].iter().enumerate() {
                    c |= (((byte >> t) & 1) as usize) << b;
                }
                bucket_add(bk, c, p, &xt[j * p..(j + 1) * p]);
            }
        }
    });
}

/// One p-lane bucket update: `buckets[c, :] += x^T[j, :]`.
#[inline]
fn bucket_add(buckets: &mut [f32], c: usize, p: usize, x_col: &[f32]) {
    let dst = &mut buckets[c * p..c * p + p];
    for (d, &xv) in dst.iter_mut().zip(x_col) {
        *d += xv;
    }
}

/// Nibble-container code row -> buckets, codes decoded in-register two
/// per byte, `j` ascending (the bit-identity contract).
fn row_buckets_nibble(
    crow: &[u8],
    n: usize,
    p: usize,
    xt: &[f32],
    buckets: &mut [f32],
) {
    for (j2, &byte) in crow.iter().enumerate() {
        let j = 2 * j2;
        bucket_add(buckets, (byte & 0x0F) as usize, p, &xt[j * p..(j + 1) * p]);
        if j + 1 < n {
            bucket_add(
                buckets,
                (byte >> 4) as usize,
                p,
                &xt[(j + 1) * p..(j + 2) * p],
            );
        }
    }
}

/// Dense 3-bit code row -> buckets, eight codes per 3-byte group.
fn row_buckets_pack3(
    crow: &[u8],
    n: usize,
    p: usize,
    xt: &[f32],
    buckets: &mut [f32],
) {
    for g in 0..n.div_ceil(8) {
        let v = crow[3 * g] as u32
            | (crow[3 * g + 1] as u32) << 8
            | (crow[3 * g + 2] as u32) << 16;
        let in_group = (n - g * 8).min(8);
        for b in 0..in_group {
            let j = g * 8 + b;
            bucket_add(
                buckets,
                ((v >> (3 * b)) & 0x7) as usize,
                p,
                &xt[j * p..(j + 1) * p],
            );
        }
    }
}

/// Shared mpGEMM driver: transpose activations once, tile output rows
/// across work-sized threads, accumulate `K*p` buckets per row, finish
/// with the codebook dot, transpose back.
fn mpgemm_driver<F>(
    codebook: &Mat,
    n: usize,
    x: &Mat,
    sc: &mut LutScratch,
    out: &mut Mat,
    fill_row: F,
) where
    F: Fn(usize, usize, &[f32], &mut [f32]) + Sync,
{
    let p = x.rows;
    let m = codebook.rows;
    let k = codebook.cols;
    assert_eq!((out.rows, out.cols), (p, m), "output shape");
    if p == 0 || m == 0 {
        return;
    }

    // x^T so each code's batch lanes are contiguous for the bucket add
    sc.xt.clear();
    sc.xt.resize(n * p, 0.0);
    for (pi, row) in x.data.chunks_exact(n).enumerate() {
        for (j, &v) in row.iter().enumerate() {
            sc.xt[j * p + pi] = v;
        }
    }
    sc.yt.clear();
    sc.yt.resize(m * p, 0.0);

    let threads = pool::threads_for(m * p * (n + k));
    let xt = &sc.xt[..];
    pool::par_rows_mut(&mut sc.yt, p, threads, |row0, chunk| {
        let mut buckets = vec![0.0f32; k * p];
        for (ri, yrow) in chunk.chunks_mut(p).enumerate() {
            let i = row0 + ri;
            buckets.fill(0.0);
            fill_row(i, p, xt, &mut buckets);
            let t = codebook.row(i);
            for (pi, y) in yrow.iter_mut().enumerate() {
                let mut acc = 0.0f32;
                for (s, &ts) in t.iter().enumerate() {
                    acc += buckets[s * p + pi] * ts;
                }
                *y = acc;
            }
        }
    });

    for (i, yrow) in sc.yt.chunks_exact(p).enumerate() {
        for (pi, &v) in yrow.iter().enumerate() {
            out.data[pi * m + i] = v;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::lut::lut_from_parts;
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn random_lut(rng: &mut Rng, m: usize, n: usize, bits: u8) -> LutLayer {
        let k = 1usize << bits;
        let codes = (0..m * n).map(|_| rng.below(k as u64) as u8).collect();
        let codebook = Mat::from_vec(m, k, rng.normal_vec_f32(m * k));
        lut_from_parts(m, n, bits, codes, codebook)
    }

    #[test]
    fn packed_matmul_matches_dequant_matmul() {
        prop::check("packed_mpgemm", 71, 14, |rng, case| {
            let m = 1 + rng.below(40) as usize;
            // force odd n on half the cases (padded-tail decode)
            let mut n = 1 + rng.below(40) as usize;
            if case % 2 == 0 && n % 2 == 0 {
                n += 1;
            }
            let p = 1 + rng.below(6) as usize;
            let bits = if rng.below(2) == 0 { 3 } else { 4 };
            let l = random_lut(rng, m, n, bits);
            let pl = PackedLut::pack(&l);
            let x = Mat::from_vec(p, n, rng.normal_vec_f32(p * n));
            let direct = x.matmul_tb(&l.dequant());
            let packed = pl.matmul(&x);
            crate::prop_assert!(
                prop::all_close(&direct.data, &packed.data, 1e-3, 1e-3),
                "maxdiff {}",
                prop::max_abs_diff(&direct.data, &packed.data)
            );
            Ok(())
        });
    }

    #[test]
    fn packed_matmul_bitwise_matches_lut_matmul() {
        // both paths share the bucket kernel's accumulation order, so
        // they must agree exactly — the batched decode engine's
        // equivalence with the sequential path rests on this
        prop::check("packed_vs_unpacked", 72, 10, |rng, _| {
            let m = 1 + rng.below(32) as usize;
            let n = 1 + rng.below(32) as usize;
            let p = 1 + rng.below(5) as usize;
            let bits = if rng.below(2) == 0 { 3 } else { 4 };
            let l = random_lut(rng, m, n, bits);
            let pl = PackedLut::pack(&l);
            let x = Mat::from_vec(p, n, rng.normal_vec_f32(p * n));
            let a = l.lut_matmul(&x);
            let b = pl.matmul(&x);
            crate::prop_assert!(a.data == b.data, "packed != unpacked");
            Ok(())
        });
    }

    #[test]
    fn batch_rows_match_single_row_calls_bitwise() {
        // bit-identity across batch sizes: row pi of the batched result
        // equals the p=1 result on that activation row alone
        let mut rng = Rng::new(73);
        let l = random_lut(&mut rng, 24, 30, 4);
        let pl = PackedLut::pack(&l);
        let p = 5;
        let x = Mat::from_vec(p, 30, rng.normal_vec_f32(p * 30));
        let batched = pl.matmul(&x);
        for pi in 0..p {
            let xr = Mat::from_vec(1, 30, x.row(pi).to_vec());
            let single = pl.matmul(&xr);
            assert_eq!(batched.row(pi), single.row(0), "row {}", pi);
        }
    }

    #[test]
    fn scratch_reuse_across_shapes_is_clean() {
        let mut rng = Rng::new(74);
        let mut sc = LutScratch::new();
        for (m, n, p) in [(8usize, 12usize, 3usize), (16, 6, 1), (4, 40, 6)] {
            let l = random_lut(&mut rng, m, n, 4);
            let pl = PackedLut::pack(&l);
            let x = Mat::from_vec(p, n, rng.normal_vec_f32(p * n));
            let mut out = Mat::zeros(p, m);
            pl.matmul_into(&x, &mut sc, &mut out);
            let fresh = pl.matmul(&x);
            assert_eq!(out.data, fresh.data);
        }
    }

    #[test]
    fn packed_bytes_match_lut_accounting() {
        let mut rng = Rng::new(75);
        for bits in [3u8, 4] {
            let l = random_lut(&mut rng, 64, 96, bits);
            let pl = PackedLut::pack(&l);
            assert_eq!(pl.bytes_per_decode(), l.bytes_per_decode());
        }
    }

    fn random_store(rng: &mut Rng, m: usize, n: usize) -> BitPlaneStore {
        BitPlaneStore::nest(&random_lut(rng, m, n, 4), &[2, 3, 4])
    }

    #[test]
    fn from_planes_byte_identical_to_packing_the_slice() {
        prop::check("from_planes_parity", 77, 10, |rng, case| {
            let m = 1 + rng.below(24) as usize;
            let mut n = 1 + rng.below(40) as usize;
            if case % 2 == 0 && n % 8 == 0 {
                n += 5; // ragged tail group
            }
            let store = random_store(rng, m, n);
            for w in [2u8, 3, 4] {
                let a = PackedLut::from_planes(&store, w);
                let b = PackedLut::pack(&store.slice(w));
                crate::prop_assert!(a.codes == b.codes, "width {} codes", w);
                crate::prop_assert!(
                    a.codebook.data == b.codebook.data
                        && a.row_bytes == b.row_bytes
                        && a.bits == b.bits,
                    "width {} meta",
                    w
                );
            }
            Ok(())
        });
    }

    #[test]
    fn planes_matmul_bitwise_matches_packed_slice() {
        // the streaming path consumes codes j-ascending like every other
        // fill_row, so all three decode paths must agree bit for bit
        prop::check("planes_mpgemm", 78, 10, |rng, case| {
            let m = 1 + rng.below(24) as usize;
            let mut n = 1 + rng.below(40) as usize;
            if case % 2 == 0 && n % 8 == 0 {
                n += 3;
            }
            let p = 1 + rng.below(5) as usize;
            let store = random_store(rng, m, n);
            let x = Mat::from_vec(p, n, rng.normal_vec_f32(p * n));
            for w in [2u8, 3, 4] {
                let mut out = Mat::zeros(p, m);
                let mut sc = LutScratch::new();
                lut_gemm_planes_into(&store, w, &x, &mut sc, &mut out);
                let packed = PackedLut::from_planes(&store, w).matmul(&x);
                let unpacked = store.slice(w).lut_matmul(&x);
                crate::prop_assert!(
                    out.data == packed.data,
                    "width {}: planes != packed",
                    w
                );
                crate::prop_assert!(
                    out.data == unpacked.data,
                    "width {}: planes != unpacked",
                    w
                );
            }
            Ok(())
        });
    }

    #[test]
    fn three_bit_rows_use_three_bits_per_code() {
        let mut rng = Rng::new(76);
        let l3 = random_lut(&mut rng, 4, 64, 3);
        let l4 = random_lut(&mut rng, 4, 64, 4);
        let p3 = PackedLut::pack(&l3);
        let p4 = PackedLut::pack(&l4);
        assert_eq!(p3.row_bytes, 64 / 8 * 3);
        assert_eq!(p4.row_bytes, 32);
        assert!(p3.codes.len() < p4.codes.len());
    }
}
