//! Outlier extraction (paper Algorithm 2, Appendix B) and GANQ* — GANQ
//! composed with the dense-and-sparse decomposition (§3.3): W is split
//! row-wise at symmetric tail percentiles into W_sparse (outliers, kept
//! FP in CSR) and W_dense (quantized by GANQ). Optionally whole rows with
//! the highest sensitivity are retained in full precision ("10 full rows",
//! the SqueezeLLM-comparable configuration of Table 5).

use crate::sparse::Csr;
use crate::tensor::Mat;

use super::{ganq::Ganq, QuantResult, Quantizer};

/// Row-wise symmetric-percentile split (Algorithm 2).
/// Returns (sparse, dense) with sparse + dense == w.
pub fn split_outliers(w: &Mat, ratio: f64) -> (Mat, Mat) {
    let (m, n) = (w.rows, w.cols);
    let p = 1.0 - 0.5 * ratio;
    let upper = ((n as f64 * p).floor() as usize).min(n - 1);
    let lower = (n as f64 * (1.0 - p)).ceil() as usize;
    let mut sparse = Mat::zeros(m, n);
    let mut dense = w.clone();
    let mut sorted = vec![0.0f32; n];
    for i in 0..m {
        sorted.copy_from_slice(w.row(i));
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let c_up = sorted[upper];
        let c_lo = sorted[lower];
        for j in 0..n {
            let v = w[(i, j)];
            if v >= c_up || v <= c_lo {
                sparse[(i, j)] = v;
                dense[(i, j)] = 0.0;
            }
        }
    }
    (sparse, dense)
}

/// Pick the `count` rows with the highest output sensitivity
/// (diag-H-weighted squared row norm) to retain at full precision.
pub fn sensitive_rows(w: &Mat, h: &Mat, count: usize) -> Vec<usize> {
    let mut scored: Vec<(f64, usize)> = (0..w.rows)
        .map(|i| {
            let s: f64 = w
                .row(i)
                .iter()
                .enumerate()
                .map(|(j, &v)| h[(j, j)] as f64 * (v as f64) * (v as f64))
                .sum();
            (s, i)
        })
        .collect();
    scored.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
    let mut rows: Vec<usize> =
        scored.into_iter().take(count).map(|(_, i)| i).collect();
    rows.sort_unstable();
    rows
}

#[derive(Debug, Clone)]
pub struct GanqStar {
    pub bits: u8,
    pub outlier_ratio: f64,
    pub full_rows: usize,
    pub iters: usize,
}

impl GanqStar {
    pub fn new(bits: u8, outlier_ratio: f64, full_rows: usize) -> Self {
        GanqStar { bits, outlier_ratio, full_rows, iters: 10 }
    }
}

impl Quantizer for GanqStar {
    fn name(&self) -> String {
        "ganq-star".to_string()
    }

    fn quantize(&self, w: &Mat, h: &Mat) -> QuantResult {
        let (m, n) = (w.rows, w.cols);
        // 1) full-precision rows (optional)
        let keep = if self.full_rows > 0 {
            sensitive_rows(w, h, self.full_rows.min(m))
        } else {
            Vec::new()
        };
        let kept: std::collections::HashSet<usize> =
            keep.iter().copied().collect();
        // 2) percentile outlier split on the remaining weights
        let (mut sparse_m, mut dense_m) = split_outliers(w, self.outlier_ratio);
        for &i in &keep {
            // whole row goes to the sparse component
            for j in 0..n {
                sparse_m[(i, j)] = w[(i, j)];
                dense_m[(i, j)] = 0.0;
            }
        }
        // 3) GANQ on the dense component
        let inner = Ganq::with_iters(self.bits, self.iters);
        let mut r = inner.quantize(&dense_m, h);
        // rows kept in FP: zero their codes' contribution by zeroing the
        // codebook row (the sparse part carries the real values)
        if let Some(lut) = &mut r.lut {
            for &i in &keep {
                for v in lut.codebook.row_mut(i) {
                    *v = 0.0;
                }
                for c in &mut lut.codes[i * n..(i + 1) * n] {
                    *c = 0;
                }
            }
            r.w_hat = lut.dequant();
        }
        let csr = Csr::from_dense(&sparse_m);
        r.w_hat.add_assign(&sparse_m);
        r.storage.sparse_bits = csr.nnz() * (16 + 32) + (m + 1) * 32;
        let _ = kept;
        QuantResult {
            method: self.name(),
            bits: self.bits,
            w_hat: r.w_hat,
            lut: r.lut,
            sparse: Some(csr),
            storage: r.storage,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::ganq::Ganq;
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn problem_with_outliers(
        rng: &mut Rng,
        m: usize,
        n: usize,
    ) -> (Mat, Mat) {
        let mut w = Mat::from_vec(m, n, rng.normal_vec_f32(m * n));
        for i in 0..m {
            let j = rng.below(n as u64) as usize;
            w[(i, j)] = 10.0 + rng.uniform() as f32 * 5.0;
        }
        let x = Mat::from_vec(n, 2 * n, rng.normal_vec_f32(2 * n * n));
        (w, x.gram())
    }

    #[test]
    fn split_reconstructs_exactly() {
        prop::check("outlier_split", 101, 8, |rng, _| {
            let m = 2 + rng.below(8) as usize;
            let n = 8 + rng.below(40) as usize;
            let w = Mat::from_vec(m, n, rng.normal_vec_f32(m * n));
            let (s, d) = split_outliers(&w, 0.1);
            for idx in 0..m * n {
                crate::prop_assert!(
                    (s.data[idx] + d.data[idx] - w.data[idx]).abs() == 0.0,
                    "not a partition at {}",
                    idx
                );
                crate::prop_assert!(
                    s.data[idx] == 0.0 || d.data[idx] == 0.0,
                    "overlap at {}",
                    idx
                );
            }
            Ok(())
        });
    }

    #[test]
    fn split_shrinks_dense_range() {
        let mut rng = Rng::new(102);
        let (w, _h) = problem_with_outliers(&mut rng, 8, 64);
        let (_s, d) = split_outliers(&w, 0.05);
        assert!(d.max_abs() < w.max_abs());
    }

    #[test]
    fn ganq_star_beats_plain_ganq_with_outliers() {
        let mut rng = Rng::new(103);
        let (w, h) = problem_with_outliers(&mut rng, 16, 64);
        let e_star = GanqStar::new(3, 0.03, 0)
            .quantize(&w, &h)
            .layer_error(&w, &h);
        let e_plain = Ganq::new(3).quantize(&w, &h).layer_error(&w, &h);
        assert!(e_star < e_plain, "star {} !< plain {}", e_star, e_plain);
    }

    #[test]
    fn full_rows_are_exact() {
        let mut rng = Rng::new(104);
        let (w, h) = problem_with_outliers(&mut rng, 12, 32);
        let r = GanqStar::new(3, 0.01, 3).quantize(&w, &h);
        let rows = sensitive_rows(&w, &h, 3);
        for &i in &rows {
            for j in 0..w.cols {
                assert!(
                    (r.w_hat[(i, j)] - w[(i, j)]).abs() < 1e-6,
                    "row {} not exact",
                    i
                );
            }
        }
    }

    #[test]
    fn sparse_density_tracks_ratio() {
        let mut rng = Rng::new(105);
        let (w, h) = problem_with_outliers(&mut rng, 16, 128);
        let r = GanqStar::new(4, 0.02, 0).quantize(&w, &h);
        let d = r.sparse.as_ref().unwrap().density();
        assert!(d > 0.005 && d < 0.08, "density {}", d);
    }

    #[test]
    fn sensitive_rows_sorted_unique() {
        let mut rng = Rng::new(106);
        let (w, h) = problem_with_outliers(&mut rng, 10, 16);
        let rows = sensitive_rows(&w, &h, 4);
        assert_eq!(rows.len(), 4);
        assert!(rows.windows(2).all(|w| w[0] < w[1]));
    }
}
