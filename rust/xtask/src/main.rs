//! `cargo xtask` — repo tooling. Subcommands:
//!
//! * `lint` — run the ganq-lint repo-invariant static analysis over
//!   `src/`, `tests/`, `benches/` (see `rust/xtask/README.md` for the
//!   rule catalogue). Exit 1 on any violation.
//! * `lint --fixtures <dir>` — lint a fixture tree instead of the crate
//!   (each fixture file's first line `//@path: <relpath>` selects the
//!   rules that apply); used by the lint's own test corpus.
//!
//! The engine source is shared with the `ganq` crate (`crate::lint::
//! engine`) via `#[path]` inclusion, so this binary needs no
//! dependencies — not even on `ganq` — and builds before the main crate
//! does.

#[path = "../../src/lint/engine.rs"]
mod engine;

use std::path::PathBuf;
use std::process::ExitCode;

fn crate_root() -> PathBuf {
    // xtask lives at <crate root>/xtask
    let manifest = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    manifest
        .parent()
        .map(|p| p.to_path_buf())
        .unwrap_or(manifest)
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(|s| s.as_str()) {
        Some("lint") => run_lint(&args[1..]),
        Some(other) => {
            eprintln!("unknown xtask subcommand {:?}; try `lint`", other);
            ExitCode::FAILURE
        }
        None => {
            eprintln!("usage: cargo xtask lint");
            ExitCode::FAILURE
        }
    }
}

fn run_lint(args: &[String]) -> ExitCode {
    let root = crate_root();
    let violations = if let Some(i) =
        args.iter().position(|a| a == "--fixtures")
    {
        let Some(dir) = args.get(i + 1) else {
            eprintln!("--fixtures needs a directory");
            return ExitCode::FAILURE;
        };
        lint_fixtures(&root, &PathBuf::from(dir))
    } else {
        engine::lint_tree(&root)
    };
    match violations {
        Ok(v) if v.is_empty() => {
            println!("ganq-lint: clean ({})", root.display());
            ExitCode::SUCCESS
        }
        Ok(v) => {
            for violation in &v {
                eprintln!("{}", violation);
            }
            eprintln!("ganq-lint: {} violation(s)", v.len());
            ExitCode::FAILURE
        }
        Err(e) => {
            eprintln!("ganq-lint: {}", e);
            ExitCode::FAILURE
        }
    }
}

/// Lint every `.rs` file under `dir` as if it lived at the path named
/// by its `//@path: <relpath>` header (defaults to the file name under
/// `src/`). The real crate's registry/rank/CI context applies.
fn lint_fixtures(
    root: &std::path::Path,
    dir: &std::path::Path,
) -> Result<Vec<engine::Violation>, String> {
    let ctx = engine::build_ctx(root)?;
    let mut out = Vec::new();
    let entries = std::fs::read_dir(dir)
        .map_err(|e| format!("read {}: {}", dir.display(), e))?;
    let mut files: Vec<PathBuf> = entries
        .flatten()
        .map(|e| e.path())
        .filter(|p| p.extension().map(|x| x == "rs") == Some(true))
        .collect();
    files.sort();
    for f in files {
        let src = std::fs::read_to_string(&f)
            .map_err(|e| format!("read {}: {}", f.display(), e))?;
        let rel = src
            .lines()
            .next()
            .and_then(|l| l.strip_prefix("//@path: "))
            .map(|p| p.trim().to_string())
            .unwrap_or_else(|| {
                format!(
                    "src/{}",
                    f.file_name().unwrap_or_default().to_string_lossy()
                )
            });
        out.extend(engine::lint_source(&rel, &src, &ctx));
    }
    Ok(out)
}
