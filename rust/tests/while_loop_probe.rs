//! Probe: does an HLO `while` loop (from lax.scan) survive the HLO-text
//! round-trip into xla_extension 0.5.1? This pins down the root cause of
//! the GANQ solver-graph divergence (see solver_pieces.rs) at the smallest
//! possible reproducer: scan body c += x over 5 steps.
//!
//! Expected with x = [1,2,3]: c = [5,10,15], ys = [1,2,3,4,5].

#[test]
fn minimal_scan_roundtrip() {
    let path = "/tmp/while_test.hlo.txt";
    if !std::path::Path::new(path).exists() {
        eprintln!("skipping: probe HLO not generated");
        return;
    }
    let client = xla::PjRtClient::cpu().unwrap();
    let proto = xla::HloModuleProto::from_text_file(path).unwrap();
    let comp = xla::XlaComputation::from_proto(&proto);
    let exe = client.compile(&comp).unwrap();
    let x = xla::Literal::vec1(&[1f32, 2f32, 3f32]);
    let out = exe.execute::<xla::Literal>(&[x]).unwrap()[0][0]
        .to_literal_sync()
        .unwrap();
    let parts = out.to_tuple().unwrap();
    let c = parts[0].to_vec::<f32>().unwrap();
    let ys = parts[1].to_vec::<f32>().unwrap();
    eprintln!("c = {:?}, ys = {:?}", c, ys);
    // length-agnostic: c = L*[1,2,3], ys = [1..L] (probe may be
    // regenerated at different lengths to toggle loop unrolling)
    let l = ys.len() as f32;
    assert_eq!(
        c,
        vec![l, 2.0 * l, 3.0 * l],
        "scan carry broken on old XLA"
    );
    for (k, &y) in ys.iter().enumerate() {
        assert_eq!(y, (k + 1) as f32, "scan stacking broken on old XLA");
    }
}
