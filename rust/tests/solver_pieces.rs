//! Piecewise validation of the AOT GANQ solver graph (Algorithm 1) against
//! the native implementation: S-step (pallas and plain jnp variants) and
//! T-step in isolation. Pinpoints any HLO-semantics gap between the jax
//! lowering and the xla_extension 0.5.1 runtime.

use ganq::quant::ganq as solver;
use ganq::quant::rtn;
use ganq::runtime::{HostTensor, Runtime};
use ganq::tensor::{linalg, Mat};
use ganq::util::rng::Rng;

fn setup() -> (Mat, Mat, Mat, Mat) {
    let mut rng = Rng::new(11);
    let w = Mat::from_vec(64, 64, rng.normal_vec_f32(64 * 64));
    let x = Mat::from_vec(64, 160, rng.normal_vec_f32(64 * 160));
    let h = x.gram();
    let hp = linalg::precondition(&h);
    let l = linalg::cholesky(&hp).unwrap();
    (w, hp, l, x)
}

#[test]
fn sstep_graphs_match_native() {
    let rt = match Runtime::load() {
        Ok(rt) => rt,
        Err(_) => return,
    };
    let (w, _hp, l, _x) = setup();
    let (_, t0) = rtn::rtn_codebook(&w, 4);
    let native = solver::sstep(&w, &l, &t0, 1);
    for graph in ["sstep4_64x64_plain", "sstep4_64x64_pallas"] {
        if !rt.has_graph(graph) {
            eprintln!("skipping {}", graph);
            continue;
        }
        let out = rt
            .run(
                graph,
                &[
                    HostTensor::F32(vec![64, 64], w.data.clone()),
                    HostTensor::F32(vec![64, 64], l.data.clone()),
                    HostTensor::F32(vec![64, 16], t0.data.clone()),
                ],
            )
            .unwrap();
        let q = out[0].as_i32().unwrap();
        let count = |f: &dyn Fn(usize, usize) -> i32| {
            (0..64 * 64)
                .filter(|&idx| {
                    let (i, j) = (idx / 64, idx % 64);
                    q[idx] != f(i, j)
                })
                .count()
        };
        let direct = count(&|i, j| native[i * 64 + j] as i32);
        let colrev = count(&|i, j| native[i * 64 + (63 - j)] as i32);
        let transp = count(&|i, j| native[j * 64 + i] as i32);
        // nearest-code assignment without any error propagation (what the
        // scan would produce if the residual accumulator never fired)
        let mut nearest = vec![0i32; 64 * 64];
        for i in 0..64 {
            for j in 0..64 {
                let e = w[(i, j)];
                let trow = t0.row(i);
                let mut best = 0;
                let mut bd = f32::INFINITY;
                for (s, &tv) in trow.iter().enumerate() {
                    if (e - tv).abs() < bd {
                        bd = (e - tv).abs();
                        best = s as i32;
                    }
                }
                nearest[i * 64 + j] = best;
            }
        }
        let vs_nearest = count(&|i, j| nearest[i * 64 + j]);
        let vs_nearest_rev = count(&|i, j| nearest[i * 64 + (63 - j)]);
        assert!(
            direct * 100 < 4096,
            "{}: direct {} colrev {} transp {} nearest {} nearestrev {} (of 4096)",
            graph,
            direct,
            colrev,
            transp,
            vs_nearest,
            vs_nearest_rev
        );
    }
}

#[test]
fn tstep_graph_matches_native() {
    let rt = match Runtime::load() {
        Ok(rt) => rt,
        Err(_) => return,
    };
    if !rt.has_graph("tstep4_64x64") {
        return;
    }
    let (w, hp, l, _x) = setup();
    let (_, t0) = rtn::rtn_codebook(&w, 4);
    let codes = solver::sstep(&w, &l, &t0, 1);
    let native_t = solver::tstep(&w, &hp, &codes, &t0, 1);
    let q_i32: Vec<i32> = codes.iter().map(|&c| c as i32).collect();
    let out = rt
        .run(
            "tstep4_64x64",
            &[
                HostTensor::F32(vec![64, 64], w.data.clone()),
                HostTensor::F32(vec![64, 64], hp.data.clone()),
                HostTensor::I32(vec![64, 64], q_i32),
                HostTensor::F32(vec![64, 16], t0.data.clone()),
            ],
        )
        .unwrap();
    let t_hlo = out[0].as_f32().unwrap();
    let maxdiff: f32 = t_hlo
        .iter()
        .zip(&native_t.data)
        .map(|(&a, &b)| (a - b).abs())
        .fold(0.0, f32::max);
    let scale = native_t.max_abs();
    assert!(
        maxdiff < 0.02 * scale + 1e-3,
        "tstep maxdiff {} (scale {})",
        maxdiff,
        scale
    );
}
