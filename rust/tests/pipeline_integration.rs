//! End-to-end pipeline integration on trained weights (no HLO required):
//! calibrate -> quantize with every method -> evaluate. Pins the paper's
//! qualitative claims at the model level. Skipped without artifacts.

use ganq::coordinator::{self, QuantEngine};
use ganq::data::corpus::{self, Split};
use ganq::eval::tasks as etasks;
use ganq::eval::{perplexity, PplEngine};
use ganq::model::forward::Weights;
use ganq::model::{ModelConfig, WeightStore};

fn trained(name: &str) -> Option<WeightStore> {
    let cfg = ModelConfig::builtin(name)?;
    let base = ganq::util::artifacts_dir();
    match WeightStore::load(&base, name, cfg) {
        Ok(s) => Some(s),
        Err(e) => {
            eprintln!("skipping: {}", e);
            None
        }
    }
}

macro_rules! require {
    ($e:expr) => {
        match $e {
            Some(v) => v,
            None => return,
        }
    };
}

#[test]
fn all_methods_quantize_trained_micro_and_order_sanely() {
    let store = require!(trained("opt-micro"));
    let calib = coordinator::calibrate(&store, 16, 128);
    let f = corpus::flavor("wiki2s").unwrap();
    let fp_ppl = {
        let mut eng = PplEngine::native(Weights::Fp(&store));
        perplexity(&mut eng, f, Split::Valid, 1).unwrap()
    };
    let mut ppls = std::collections::BTreeMap::new();
    for method in ["rtn", "gptq", "omniq", "ganq"] {
        let qm = coordinator::quantize_model(
            &store,
            method,
            3,
            &calib,
            &QuantEngine::Native,
            false,
        )
        .unwrap();
        let mut eng = PplEngine::native(Weights::Quant(&qm));
        let ppl = perplexity(&mut eng, f, Split::Valid, 1).unwrap();
        ppls.insert(method.to_string(), ppl);
    }
    // the paper's headline ordering at 3-bit: GANQ closest to FP16,
    // RTN worst. (gptq/omniq relative order can wobble at tiny scale.)
    assert!(ppls["ganq"] >= fp_ppl * 0.98, "{:?} fp={}", ppls, fp_ppl);
    assert!(
        ppls["ganq"] <= ppls["rtn"],
        "ganq {} !<= rtn {}",
        ppls["ganq"],
        ppls["rtn"]
    );
    assert!(
        ppls["ganq"] <= ppls["gptq"] * 1.02
            && ppls["ganq"] <= ppls["omniq"] * 1.02,
        "{:?}",
        ppls
    );
    // and the absolute gap from FP16 must be small at 3 bits for GANQ
    assert!(
        ppls["ganq"] < fp_ppl * 2.0,
        "ganq 3-bit collapsed: {} vs fp {}",
        ppls["ganq"],
        fp_ppl
    );
}

#[test]
fn outlier_methods_improve_over_plain_at_3bit() {
    let store = require!(trained("opt-micro"));
    let calib = coordinator::calibrate(&store, 16, 128);
    let e = |method: &str| {
        let qm = coordinator::quantize_model(
            &store,
            method,
            3,
            &calib,
            &QuantEngine::Native,
            false,
        )
        .unwrap();
        coordinator::pipeline::total_layer_error(&store, &qm, &calib)
    };
    let plain = e("ganq");
    let star = e("ganq-star");
    assert!(
        star <= plain * 1.001,
        "ganq* {} !<= ganq {}",
        star,
        plain
    );
    let sq = e("squeezellm");
    assert!(sq < e("rtn-g128"), "squeezellm should beat grouped rtn");
}

#[test]
fn zero_shot_accuracy_degrades_gracefully() {
    // Table 3's shape: trained model beats chance; 4-bit GANQ stays close
    let store = require!(trained("opt-small"));
    let w = Weights::Fp(&store);
    let (_rows, mean_fp) = etasks::zero_shot_suite(&w, 20, 5);
    assert!(mean_fp > 60.0, "trained model should beat chance: {}", mean_fp);
    let calib = coordinator::calibrate(&store, 16, 128);
    let qm = coordinator::quantize_model(
        &store,
        "ganq",
        4,
        &calib,
        &QuantEngine::Native,
        false,
    )
    .unwrap();
    let wq = Weights::Quant(&qm);
    let (_rows, mean_q) = etasks::zero_shot_suite(&wq, 20, 5);
    assert!(
        mean_q > mean_fp - 12.0,
        "4-bit ganq collapsed on tasks: {} vs {}",
        mean_q,
        mean_fp
    );
}

#[test]
fn instruct_model_solves_tasks_and_quantized_keeps_most() {
    let store = require!(trained("opt-mini-instruct"));
    let w = Weights::Fp(&store);
    let gsm = ganq::data::tasks::gsm_cases(30, 11);
    let acc_fp = etasks::exact_match(&w, &gsm);
    assert!(
        acc_fp > 0.5,
        "instruct model should solve most single-digit sums: {}",
        acc_fp
    );
    let calib = coordinator::calibrate(&store, 16, 128);
    let qm = coordinator::quantize_model(
        &store,
        "ganq",
        4,
        &calib,
        &QuantEngine::Native,
        false,
    )
    .unwrap();
    let acc_q = etasks::exact_match(&Weights::Quant(&qm), &gsm);
    assert!(
        acc_q >= acc_fp - 0.3,
        "4-bit ganq collapsed on gsm-s: {} vs {}",
        acc_q,
        acc_fp
    );
}

#[test]
fn longbench_recall_works_on_instruct() {
    // kv recall is the hardest task for these tiny models (Table 4's
    // longbench-s column sits at ~20-24% vs 10% digit chance); the test
    // pins "clearly above chance", the bench reports the full picture
    let store = require!(trained("opt-small-instruct"));
    let w = Weights::Fp(&store);
    let cases = ganq::data::tasks::longbench_cases(60, 8, 13);
    let acc = etasks::exact_match(&w, &cases);
    assert!(acc > 0.15, "kv recall at/below chance: {}", acc);
}

#[test]
fn quantization_cost_scales_reasonably() {
    // §4.4: GANQ quantizes a model quickly; sanity-bound wall time
    let store = require!(trained("opt-micro"));
    let calib = coordinator::calibrate(&store, 8, 64);
    let t0 = std::time::Instant::now();
    let _ = coordinator::quantize_model(
        &store,
        "ganq",
        4,
        &calib,
        &QuantEngine::Native,
        false,
    )
    .unwrap();
    let dt = t0.elapsed().as_secs_f64();
    assert!(dt < 120.0, "ganq on opt-micro took {}s", dt);
}
