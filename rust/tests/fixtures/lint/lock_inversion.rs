//@path: src/main.rs
//! Seeded violation: nested acquisition in decreasing rank order
//! (lock-rank). CLUSTER_STATUS (20) is held when TRACE_SINK (10) is
//! taken; ranks must be strictly increasing inward.

use ganq::util::ordered_lock::{rank, OrderedMutex};

pub fn inverted() -> u32 {
    let hi = OrderedMutex::new(rank::CLUSTER_STATUS, "fixture.hi", 1u32);
    let lo = OrderedMutex::new(rank::TRACE_SINK, "fixture.lo", 2u32);
    let g1 = hi.lock();
    let g2 = lo.lock();
    *g1 + *g2
}
