//@path: src/coordinator/serve.rs
//! Seeded violations: a trace name missing from obs::names::TRACE_NAMES
//! and a non-literal trace name (trace-registry, twice).

use ganq::obs::trace;

pub fn bad_literal() {
    let _sp = trace::span("bogus.not_in_registry");
}

pub fn non_literal(name: &'static str) {
    let _sp = trace::span(name);
}
