//@path: src/model/forward.rs
//! Seeded violation: integer-literal indexing, no bound comment
//! (hot-index). The blank line below keeps the doc comment from
//! counting as a bound comment for the indexing line.

pub fn first(xs: &[f32]) -> f32 {
    xs[0]
}
