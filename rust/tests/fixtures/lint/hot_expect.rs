//@path: src/kv/paged.rs
//! Seeded violation: `.expect()` without a lint:allow (hot-expect).

pub fn take(v: Option<u32>) -> u32 {
    v.expect("always some")
}
