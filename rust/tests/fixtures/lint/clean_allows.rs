//@path: src/coordinator/serve.rs
//! Clean fixture: every rule that applies to a serve hot path is
//! satisfied through its documented escape hatch, so linting this file
//! must yield zero violations.

use ganq::obs::trace;

pub fn escapes(v: Option<u32>, xs: &[u32]) -> u32 {
    // lint:allow(hot-expect): fixture invariant — caller passes Some
    let a = v.expect("always some");
    let b = xs[0]; // bound: xs nonempty by construction
    let _sp = trace::span("engine.step");
    a + b
}

pub fn documented_unsafe(p: *const u8) -> u8 {
    // SAFETY: fixture contract — p points at a live, aligned byte
    unsafe { *p }
}
