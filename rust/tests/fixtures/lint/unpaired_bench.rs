//@path: src/bench/results.rs
//! Seeded violation: a BENCH_*.json artifact with no schema-gate step
//! in .github/workflows/ci.yml (bench-gate).

pub fn emit() {
    std::fs::write("BENCH_unpaired.json", "{}").ok();
}
