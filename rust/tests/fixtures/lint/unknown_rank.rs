//@path: src/coordinator/server.rs
//! Seeded violation: an OrderedMutex built with a rank constant that is
//! not in util::ordered_lock::rank's declared table (lock-rank).

use ganq::util::ordered_lock::{rank, OrderedMutex};

pub fn bogus() -> OrderedMutex<u32> {
    OrderedMutex::new(rank::NOT_A_DECLARED_RANK, "fixture.bogus", 0u32)
}
