//@path: src/coordinator/cluster.rs
//! Seeded violations: raw std Mutex in a lock-ranked module (raw-mutex,
//! once per mention).

use std::sync::Mutex;

pub fn make() -> Mutex<u32> {
    Mutex::new(0)
}
