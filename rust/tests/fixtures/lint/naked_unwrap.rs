//@path: src/coordinator/serve.rs
//! Seeded violation: bare `.unwrap()` on a serve hot path (hot-unwrap).

pub fn take(v: Option<u32>) -> u32 {
    v.unwrap()
}
