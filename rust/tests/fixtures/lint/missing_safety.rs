//@path: src/util/buf.rs
//! Seeded violation: `unsafe` with no `// SAFETY:` comment within 10
//! lines above (safety-comment). Padding pushes the doc block out of
//! the lookback window so it cannot satisfy the rule by accident.
//!
//! pad
//! pad
//! pad
//! pad
//! pad
//! pad
//! pad

pub fn deref(p: *const u8) -> u8 {
    unsafe { *p }
}
