//@path: src/quant/kernels.rs
//! Seeded violation: panic! on a serve hot path (hot-panic).

pub fn reject(w: u8) {
    panic!("width {} unsupported", w);
}
