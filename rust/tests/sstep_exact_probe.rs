//! Third probe: the EXACT compile.ganq.sstep code at miniature size
//! (m=2, n=4, 2-bit) through the HLO-text round-trip.
//! Expected q (from jax): [0,1,2,3, 0,1,2,3].

#[test]
fn exact_sstep_miniature() {
    let path = "/tmp/sstep_exact.hlo.txt";
    if !std::path::Path::new(path).exists() {
        eprintln!("skipping: probe HLO not generated");
        return;
    }
    let client = xla::PjRtClient::cpu().unwrap();
    let proto = xla::HloModuleProto::from_text_file(path).unwrap();
    let comp = xla::XlaComputation::from_proto(&proto);
    let exe = client.compile(&comp).unwrap();
    let w: Vec<f32> = (0..8).map(|i| i as f32 * 0.3).collect();
    let mut l = vec![0f32; 16];
    for i in 0..4 {
        for j in 0..=i {
            l[i * 4 + j] = 1.0;
        }
        l[i * 4 + i] = 2.0;
    }
    let t0: Vec<f32> = vec![0.0, 0.3, 0.6, 0.9, 1.2, 1.5, 1.8, 2.1];
    let args = [
        xla::Literal::vec1(&w).reshape(&[2, 4]).unwrap(),
        xla::Literal::vec1(&l).reshape(&[4, 4]).unwrap(),
        xla::Literal::vec1(&t0).reshape(&[2, 4]).unwrap(),
    ];
    let out = exe.execute::<xla::Literal>(&args).unwrap()[0][0]
        .to_literal_sync()
        .unwrap();
    let parts = out.to_tuple().unwrap();
    let q = parts[0].to_vec::<i32>().unwrap();
    eprintln!("q = {:?}", q);
    assert_eq!(q, vec![0, 1, 2, 3, 0, 1, 2, 3]);
}
