//! Engine step integration tests: multi-item `Engine::step` pinned
//! against per-sequence single-item steps across mixed batch sizes,
//! ragged positions, dense/LUT/LutSparse linears, and contiguous/paged
//! (F32 + LUT) KV stores. Dense stores must agree **bitwise**; LUT block
//! stores within 1e-3.

use std::collections::BTreeMap;

use ganq::kv::{F32Blocks, KvLayout, LutBlocks, PagedKv};
use ganq::model::forward::{Engine, KvCache, KvSeq, SeqRefs, Weights};
use ganq::model::{LayerWeights, ModelConfig, QuantizedModel, WeightStore};
use ganq::quant::ganq::fit_codebook_identity;
use ganq::quant::lut::{lut_from_parts, LutLayer};
use ganq::sparse::Csr;
use ganq::tensor::Mat;
use ganq::util::prop;
use ganq::util::rng::Rng;

fn micro_store(seed: u64) -> WeightStore {
    let cfg = ModelConfig::builtin("opt-micro").unwrap();
    WeightStore::random("t", cfg, seed)
}

/// One single-position step for one sequence (the per-token reference).
fn decode_one(engine: &mut Engine, tok: i32, cache: &mut dyn KvSeq) -> Vec<f32> {
    let mut refs: Vec<&mut dyn KvSeq> = vec![cache];
    engine
        .decode_batch(&[tok], &mut SeqRefs(&mut refs))
        .into_iter()
        .next()
        .unwrap()
}

/// Per-row non-uniform LUT fit of a dense weight (identity Hessian).
fn lut_layer_from(w: &Mat, bits: u8) -> LutLayer {
    let k = 1usize << bits;
    let mut codes = vec![0u8; w.rows * w.cols];
    let mut cb = Mat::zeros(w.rows, k);
    for i in 0..w.rows {
        let (c, t) = fit_codebook_identity(w.row(i), bits, 2);
        codes[i * w.cols..(i + 1) * w.cols].copy_from_slice(&c);
        cb.row_mut(i).copy_from_slice(&t);
    }
    lut_from_parts(w.rows, w.cols, bits, codes, cb)
}

/// A quantized model cycling through every linear representation the
/// engine dispatches on: Dense, 4-bit LUT, 3-bit LUT, LUT+sparse — plus
/// one linear left unquantized (the base-store fallback).
fn mixed_quant(store: &WeightStore, seed: u64) -> QuantizedModel {
    let mut rng = Rng::new(seed);
    let mut linears = BTreeMap::new();
    for (idx, (name, _m, _n)) in
        store.cfg.linear_shapes().into_iter().enumerate()
    {
        if idx == 5 {
            continue; // exercise the missing-linear fallback
        }
        let w = store.mat(&name);
        let lw = match idx % 4 {
            0 => LayerWeights::Dense(w),
            1 => LayerWeights::Lut(lut_layer_from(&w, 4)),
            2 => LayerWeights::Lut(lut_layer_from(&w, 3)),
            _ => {
                let lut = lut_layer_from(&w, 4);
                let mut sp = Mat::zeros(w.rows, w.cols);
                for _ in 0..8 {
                    let i = rng.below(w.rows as u64) as usize;
                    let j = rng.below(w.cols as u64) as usize;
                    sp[(i, j)] = rng.normal() as f32 * 0.1;
                }
                LayerWeights::LutSparse(lut, Csr::from_dense(&sp))
            }
        };
        linears.insert(name, lw);
    }
    QuantizedModel {
        base: store.clone(),
        method: "mixed-test".into(),
        bits: 4,
        linears,
        weight_bits: 0,
    }
}

/// Drive 3 batched decode steps over contiguous caches and check
/// each against per-sequence single-item steps on cloned caches.
fn check_contiguous(w: &Weights, caches: &mut [KvCache], rng: &mut Rng) {
    let mut engine = Engine::new(w);
    let mut eng_ref = Engine::new(w);
    for _ in 0..3 {
        let toks: Vec<i32> =
            caches.iter().map(|_| rng.below(256) as i32).collect();
        let mut seq_caches: Vec<KvCache> = caches.to_vec();
        let expect: Vec<Vec<f32>> = toks
            .iter()
            .zip(&mut seq_caches)
            .map(|(&t, c)| decode_one(&mut eng_ref, t, c))
            .collect();
        let mut refs: Vec<&mut dyn KvSeq> = caches
            .iter_mut()
            .map(|c| c as &mut dyn KvSeq)
            .collect();
        let got = engine.decode_batch(&toks, &mut SeqRefs(&mut refs));
        assert_eq!(got, expect, "batched != per-sequence (dense store)");
        for (c, s) in caches.iter_mut().zip(seq_caches) {
            *c = s; // keep both paths on the sequentially-written state
        }
    }
}

#[test]
fn batched_matches_sequential_fp_ragged_batches() {
    let store = micro_store(81);
    let w = Weights::Fp(&store);
    let mut rng = Rng::new(811);
    let mut warm = Engine::new(&w);
    for b in [1usize, 2, 4, 5] {
        let mut caches = vec![KvCache::new(store.cfg); b];
        // ragged warmup: every sequence at a different position
        for (i, c) in caches.iter_mut().enumerate() {
            for _ in 0..=(3 * i) % 7 {
                decode_one(&mut warm, rng.below(256) as i32, c);
            }
        }
        check_contiguous(&w, &mut caches, &mut rng);
    }
}

#[test]
fn batched_matches_sequential_mixed_quant_bitwise() {
    // dense KV store + quantized weights (packed LUT kernels, sparse
    // branch, dense fallback): still bit-identical to the per-sequence
    // path — the packed and unpacked kernels share accumulation order
    let store = micro_store(82);
    let qm = mixed_quant(&store, 821);
    let w = Weights::Quant(&qm);
    let mut rng = Rng::new(822);
    let mut warm = Engine::new(&w);
    for b in [1usize, 3, 4] {
        let mut caches = vec![KvCache::new(store.cfg); b];
        for (i, c) in caches.iter_mut().enumerate() {
            for _ in 0..(5 * i + 1) % 6 {
                decode_one(&mut warm, rng.below(256) as i32, c);
            }
        }
        check_contiguous(&w, &mut caches, &mut rng);
    }
}

#[test]
fn chunked_prefill_mixed_quant_bitwise() {
    // quantized weights, dense KV: one prefill chunk must be bitwise
    // identical to per-token feeding (the multi-row LUT kernels share
    // accumulation order with the single-row path)
    let store = micro_store(86);
    let qm = mixed_quant(&store, 861);
    let w = Weights::Quant(&qm);
    let prompt: Vec<i32> = (0..19).map(|i| (i * 17 + 3) % 256).collect();

    let mut eng_ref = Engine::new(&w);
    let mut c_ref = KvCache::new(store.cfg);
    let mut last_ref = Vec::new();
    for &t in &prompt {
        last_ref = decode_one(&mut eng_ref, t, &mut c_ref);
    }

    let mut engine = Engine::new(&w);
    let mut cache = KvCache::new(store.cfg);
    use ganq::model::forward::{LogitsMode, StepItem, StepPlan};
    let plan = StepPlan {
        items: vec![StepItem::prefill(0, prompt.clone(), LogitsMode::Last)],
    };
    let mut refs: Vec<&mut dyn KvSeq> = vec![&mut cache];
    let outs = engine.step(&plan, &mut SeqRefs(&mut refs));
    assert_eq!(outs[0].data, last_ref, "chunked prefill diverged");
}

#[test]
fn batched_membership_changes_match_sequential() {
    // continuous-batching shape: sequences join and leave the batch
    // between steps; per-sequence results must not depend on who else
    // is in the step
    let store = micro_store(83);
    let qm = mixed_quant(&store, 831);
    let w = Weights::Quant(&qm);
    let mut engine = Engine::new(&w);
    let mut eng_ref = Engine::new(&w);
    let mut rng = Rng::new(832);
    let mut batched: Vec<KvCache> = vec![KvCache::new(store.cfg); 4];
    let mut sequential = batched.clone();
    let subsets: [&[usize]; 4] = [&[0, 1, 2, 3], &[0, 2], &[1], &[1, 3]];
    for subset in subsets {
        let toks: Vec<i32> =
            subset.iter().map(|_| rng.below(256) as i32).collect();
        let expect: Vec<Vec<f32>> = subset
            .iter()
            .zip(&toks)
            .map(|(&i, &t)| decode_one(&mut eng_ref, t, &mut sequential[i]))
            .collect();
        let mut refs: Vec<&mut dyn KvSeq> = Vec::new();
        let mut rest: &mut [KvCache] = &mut batched;
        let mut base = 0usize;
        for &i in subset {
            let (_, tail) = rest.split_at_mut(i - base);
            let (c, tail) = tail.split_first_mut().unwrap();
            refs.push(c);
            rest = tail;
            base = i + 1;
        }
        let got = engine.decode_batch(&toks, &mut SeqRefs(&mut refs));
        assert_eq!(got, expect, "subset {:?}", subset);
    }
}

#[test]
fn batched_paged_f32_matches_sequential_contiguous_bitwise() {
    let store = micro_store(84);
    let cfg = store.cfg;
    let w = Weights::Fp(&store);
    let prompts: [&[i32]; 3] = [&[1, 2, 3, 4, 5], &[9, 8], &[50]];
    let new_tokens = 6usize;

    // per-sequence contiguous reference
    let mut eng_ref = Engine::new(&w);
    let mut reference: Vec<Vec<Vec<f32>>> = Vec::new();
    for p in &prompts {
        let mut c = KvCache::new(cfg);
        let mut logits = Vec::new();
        for &t in *p {
            logits.push(decode_one(&mut eng_ref, t, &mut c));
        }
        for s in 0..new_tokens {
            logits.push(decode_one(&mut eng_ref, (60 + s) as i32, &mut c));
        }
        reference.push(logits);
    }

    // batched over a paged F32 store: prompts fed raggedly (sequence i
    // joins the batch only once earlier ones are past their prompts)
    let layout = KvLayout::new(&cfg, 4);
    let mut kv =
        PagedKv::new(Box::new(F32Blocks::new(layout, 64)), 64, 3);
    for (slot, p) in prompts.iter().enumerate() {
        assert_eq!(kv.admit(slot, p, new_tokens), Some(0));
    }
    let mut engine = Engine::new(&w);
    let mut fed = [0usize; 3]; // tokens fed so far per slot
    let total: Vec<usize> =
        prompts.iter().map(|p| p.len() + new_tokens).collect();
    while (0..3).any(|i| fed[i] < total[i]) {
        let slots: Vec<usize> =
            (0..3).filter(|&i| fed[i] < total[i]).collect();
        let active: Vec<bool> =
            (0..3).map(|i| slots.contains(&i)).collect();
        assert!(kv.prepare_step(&active).is_empty(), "no preemption");
        let toks: Vec<i32> = slots
            .iter()
            .map(|&i| {
                let t = if fed[i] < prompts[i].len() {
                    prompts[i][fed[i]]
                } else {
                    (60 + (fed[i] - prompts[i].len())) as i32
                };
                kv.push_token(i, t);
                t
            })
            .collect();
        let mut seqs = kv.seqs(slots.clone());
        let got = engine.decode_batch(&toks, &mut seqs);
        for (row, &slot) in got.iter().zip(&slots) {
            assert_eq!(
                row, &reference[slot][fed[slot]],
                "slot {} step {}",
                slot, fed[slot]
            );
            fed[slot] += 1;
        }
    }
}

#[test]
fn batched_paged_lut_matches_sequential_paged_lut() {
    // quantized KV blocks: batched and sequential read the same
    // dequantized rows, so they stay within 1e-3 of each other
    let store = micro_store(85);
    let cfg = store.cfg;
    let w = Weights::Fp(&store);
    let seq: Vec<i32> = (0..18).map(|i| (i * 11 + 2) % 256).collect();
    let layout = KvLayout::new(&cfg, 4);

    let mut kv_s =
        PagedKv::new(Box::new(LutBlocks::new(layout, 32)), 32, 1);
    kv_s.admit(0, &seq, 1).unwrap();
    let mut eng_ref = Engine::new(&w);
    let mut sequential = Vec::new();
    for &t in &seq {
        assert!(kv_s.prepare_step(&[true]).is_empty());
        kv_s.push_token(0, t);
        let mut view = kv_s.slot_view(0);
        sequential.push(decode_one(&mut eng_ref, t, &mut view));
    }
    assert!(kv_s.stats().sealed_blocks > 0, "blocks must have sealed");

    let mut kv_b =
        PagedKv::new(Box::new(LutBlocks::new(layout, 32)), 32, 1);
    kv_b.admit(0, &seq, 1).unwrap();
    let mut engine = Engine::new(&w);
    for (si, &t) in seq.iter().enumerate() {
        assert!(kv_b.prepare_step(&[true]).is_empty());
        kv_b.push_token(0, t);
        let mut seqs = kv_b.seqs(vec![0]);
        let got = engine.decode_batch(&[t], &mut seqs);
        assert!(
            prop::all_close(&got[0], &sequential[si], 1e-3, 1e-3),
            "step {}: maxdiff {}",
            si,
            prop::max_abs_diff(&got[0], &sequential[si])
        );
    }
}
