//! Request-lifecycle property tests: sampler determinism across batch
//! sizes, prefill chunking, and preempt-and-resume; temperature-0
//! bitwise equality with the pre-lifecycle greedy path; and the
//! mixed-parameter acceptance batch (greedy + sampled + stop-seq +
//! cancelled in one `serve_events` call, with streaming).

use ganq::coordinator::{
    serve, serve_events, serve_with, FinishReason, GenRequest,
    KvStoreKind, NativeBackend, PagedNativeBackend, SamplingParams,
    ServeOptions, StopCriteria, TokenEvent,
};
use ganq::model::forward::{
    self, Engine, KvCache, KvSeq, SeqRefs, Weights,
};
use ganq::model::{ModelConfig, WeightStore};

fn store() -> WeightStore {
    let cfg = ModelConfig::builtin("opt-micro").unwrap();
    WeightStore::random("sampling", cfg, 4242)
}

/// A mixed workload of greedy and sampled requests with ragged prompts.
fn workload(n: usize, max_new: usize) -> Vec<GenRequest> {
    (0..n as u64)
        .map(|i| {
            let prompt: Vec<i32> = (0..5 + (i as i32 % 7) * 3)
                .map(|j| (j * 17 + i as i32 * 11) % 256)
                .collect();
            let sampling = if i % 2 == 0 {
                SamplingParams::greedy()
            } else {
                SamplingParams::sample(0.9, 1000 + i)
                    .with_top_k(64)
                    .with_top_p(0.97)
            };
            GenRequest::new(
                i,
                prompt,
                sampling,
                StopCriteria::max_tokens(max_new),
            )
        })
        .collect()
}

fn tokens_by_id(resp: &[ganq::coordinator::GenOutcome]) -> Vec<Vec<i32>> {
    let mut v: Vec<_> = resp.to_vec();
    v.sort_by_key(|r| r.id);
    v.into_iter().map(|r| r.tokens).collect()
}

#[test]
fn sampled_outputs_identical_across_batch_sizes() {
    let s = store();
    let reqs = workload(8, 10);
    let mut outs = Vec::new();
    for slots in [1usize, 4, 16] {
        let w = Weights::Fp(&s);
        let mut be = NativeBackend::new(w, slots);
        let (resp, _) = serve(&mut be, reqs.clone()).unwrap();
        outs.push(tokens_by_id(&resp));
    }
    assert_eq!(outs[0], outs[1], "batch 1 vs 4 diverged");
    assert_eq!(outs[0], outs[2], "batch 1 vs 16 diverged");
}

#[test]
fn sampled_outputs_identical_across_prefill_chunks() {
    let s = store();
    let reqs = workload(6, 8);
    let mut outs = Vec::new();
    for chunk in [1usize, 128] {
        let w = Weights::Fp(&s);
        let mut be = NativeBackend::new(w, 3);
        let (resp, _) = serve_with(
            &mut be,
            reqs.clone(),
            ServeOptions { prefill_chunk: chunk, ..Default::default() },
        )
        .unwrap();
        outs.push(tokens_by_id(&resp));
    }
    assert_eq!(outs[0], outs[1], "chunk 1 vs 128 diverged");
}

#[test]
fn sampled_outputs_survive_preempt_and_resume() {
    let s = store();
    // sampled requests long enough that a tiny paged pool must preempt
    let reqs: Vec<GenRequest> = (0..4u64)
        .map(|i| {
            GenRequest::new(
                i,
                vec![10 + i as i32, 20, 30],
                SamplingParams::sample(1.0, 500 + i).with_top_k(32),
                StopCriteria::max_tokens(12),
            )
        })
        .collect();
    let w = Weights::Fp(&s);
    let mut be = NativeBackend::new(w, 4);
    let (expect, _) = serve(&mut be, reqs.clone()).unwrap();

    let w2 = Weights::Fp(&s);
    let mut bp = PagedNativeBackend::new(w2, 4, 4, 8, KvStoreKind::F32);
    let (got, m) = serve(&mut bp, reqs).unwrap();
    assert_eq!(expect.len(), got.len());
    for (e, g) in expect.iter().zip(&got) {
        assert_eq!(e.id, g.id);
        assert_eq!(e.tokens, g.tokens, "req {} diverged", e.id);
    }
    // the pool is too small for 4 concurrent requests: the equality
    // above must have held across preemption or serialization
    assert!(m.preemptions > 0 || m.peak_concurrency < 4);
}

#[test]
fn temperature_zero_bitwise_matches_greedy_reference() {
    // the pre-lifecycle greedy path: per-token argmax decode through the
    // raw engine, no sampler anywhere
    let s = store();
    let w = Weights::Fp(&s);
    let prompt: Vec<i32> = vec![104, 101, 108, 108, 111];
    let max_new = 10;
    let mut engine = Engine::new(&w);
    let mut cache = KvCache::new(s.cfg);
    let mut logits = Vec::new();
    for &t in &prompt {
        let mut refs: Vec<&mut dyn KvSeq> = vec![&mut cache];
        logits = engine
            .decode_batch(&[t], &mut SeqRefs(&mut refs))
            .into_iter()
            .next()
            .unwrap();
    }
    let mut reference = Vec::new();
    for _ in 0..max_new {
        let next = forward::argmax(&logits) as i32;
        reference.push(next);
        let mut refs: Vec<&mut dyn KvSeq> = vec![&mut cache];
        logits = engine
            .decode_batch(&[next], &mut SeqRefs(&mut refs))
            .into_iter()
            .next()
            .unwrap();
    }

    // Engine::generate with greedy params
    let gen = Engine::new(&w).generate(
        &prompt,
        max_new,
        &SamplingParams::greedy(),
    );
    assert_eq!(gen, reference, "Engine::generate diverged from argmax");

    // temperature-0 through the full serve scheduler — even with a seed
    // and truncation settings present, temperature 0 must ignore them
    let sampling = SamplingParams {
        temperature: 0.0,
        top_k: 3,
        top_p: 0.5,
        seed: 999,
    };
    let req = GenRequest::new(
        1,
        prompt.clone(),
        sampling,
        StopCriteria::max_tokens(max_new),
    );
    let mut be = NativeBackend::new(w, 2);
    let (resp, _) = serve(&mut be, vec![req]).unwrap();
    assert_eq!(resp[0].tokens, reference, "served greedy diverged");
    assert_eq!(resp[0].finish, FinishReason::MaxTokens);
}

#[test]
fn mixed_parameter_batch_with_streaming_and_cancellation() {
    // the acceptance batch: greedy + sampled + stop-sequence + cancelled
    // requests served together, with token events streaming before
    // completion and per-request finish reasons
    let s = store();
    let w = Weights::Fp(&s);
    let prompt: Vec<i32> = vec![104, 105, 106];
    let max_new = 10;
    let greedy_full = Engine::new(&w).generate(
        &prompt,
        max_new,
        &SamplingParams::greedy(),
    );
    // a stop anchor that cannot fire earlier (first occurrence)
    let k = (0..greedy_full.len())
        .rev()
        .find(|&k| !greedy_full[..k].contains(&greedy_full[k]))
        .unwrap();
    let (stop_seq, stop_expect) = if k >= 1 {
        (
            greedy_full[k - 1..=k].to_vec(),
            greedy_full[..k - 1].to_vec(),
        )
    } else {
        (vec![greedy_full[0]], Vec::new())
    };

    let reqs = vec![
        GenRequest::greedy(1, prompt.clone(), max_new),
        GenRequest::new(
            2,
            prompt.clone(),
            SamplingParams::sample(0.8, 77).with_top_k(40).with_top_p(0.95),
            StopCriteria::max_tokens(max_new),
        ),
        GenRequest::new(
            3,
            prompt.clone(),
            SamplingParams::greedy(),
            StopCriteria::max_tokens(max_new).with_stop_seq(stop_seq),
        ),
        GenRequest::greedy(4, prompt.clone(), max_new),
    ];
    let cancel = reqs[3].cancel_handle();

    let mut be = NativeBackend::new(w, 4);
    let mut events: Vec<(u64, bool)> = Vec::new();
    let mut req4_tokens = 0usize;
    let (resp, m) = serve_events(
        &mut be,
        reqs,
        ServeOptions::default(),
        &mut |ev| {
            match &ev {
                TokenEvent::Token { id, .. } => {
                    events.push((*id, false));
                    if *id == 4 {
                        req4_tokens += 1;
                        if req4_tokens == 2 {
                            cancel.cancel();
                        }
                    }
                }
                TokenEvent::Done(o) => events.push((o.id, true)),
            };
        },
    )
    .unwrap();

    let by_id = |id: u64| resp.iter().find(|r| r.id == id).unwrap();
    // greedy rides the same batch as everything else and stays exact
    assert_eq!(by_id(1).tokens, greedy_full);
    assert_eq!(by_id(1).finish, FinishReason::MaxTokens);
    // sampled request: reproducible against a solo rerun of the same seed
    let w2 = Weights::Fp(&s);
    let solo = Engine::new(&w2).generate(
        &prompt,
        max_new,
        &SamplingParams::sample(0.8, 77).with_top_k(40).with_top_p(0.95),
    );
    assert_eq!(by_id(2).tokens, solo, "sampled req not batch-invariant");
    assert_eq!(by_id(2).finish, FinishReason::MaxTokens);
    // stop-sequence request trims the matched tail
    assert_eq!(by_id(3).finish, FinishReason::StopSeq);
    assert_eq!(by_id(3).tokens, stop_expect);
    // cancelled request stopped at the next step boundary
    assert_eq!(by_id(4).finish, FinishReason::Cancelled);
    assert_eq!(by_id(4).tokens.len(), 2);
    assert_eq!(m.finish.cancelled, 1);
    assert_eq!(m.finish.stop_seq, 1);
    assert_eq!(m.cancelled_tokens, 2);

    // streaming: every request's first Token event precedes its own
    // Done, and the batch genuinely interleaves — the long greedy
    // request keeps streaming after the cancelled request completed
    for id in 1..=4u64 {
        let first_tok =
            events.iter().position(|(i, d)| *i == id && !*d).unwrap();
        let done = events.iter().position(|(i, d)| *i == id && *d).unwrap();
        assert!(first_tok < done, "req {} did not stream", id);
    }
    let done4 = events.iter().position(|(i, d)| *i == 4 && *d).unwrap();
    assert!(
        events[done4..].iter().any(|(i, d)| *i == 1 && !*d),
        "no token streamed after an earlier request completed"
    );
}
