//! HLO-path integration: every class of AOT artifact executed through the
//! PJRT runtime and cross-checked against the Rust-native implementation.
//! These are the tests proving the three layers compose. Skipped when
//! artifacts are absent.

use ganq::coordinator::{self, GenRequest, QuantEngine, WeightFmt};
use ganq::data::corpus::{self, Split};
use ganq::eval::{self, PplEngine};
use ganq::model::forward::Weights;
use ganq::model::{ModelConfig, WeightStore};
use ganq::quant::Quantizer;
use ganq::runtime::{ganq_hlo, HostTensor, Runtime};
use ganq::tensor::{linalg, Mat};
use ganq::util::rng::Rng;

fn runtime() -> Option<Runtime> {
    match Runtime::load() {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("skipping HLO tests: {}", e);
            None
        }
    }
}

fn store_for(rt: &Runtime, model: &str) -> Option<WeightStore> {
    let cfg = rt.manifest.models.get(model)?.config;
    WeightStore::load(&rt.base, model, cfg).ok()
}

macro_rules! require {
    ($e:expr) => {
        match $e {
            Some(v) => v,
            None => return,
        }
    };
}

#[test]
fn lutgemm_kernel_artifact_matches_native() {
    let rt = require!(runtime());
    for bits in [4u8, 3] {
        let name = format!("lutgemm{}_p8_128x128", bits);
        if !rt.has_graph(&name) {
            eprintln!("skipping: {} not built", name);
            continue;
        }
        let mut rng = Rng::new(7);
        let k = 1usize << bits;
        let codes: Vec<u8> =
            (0..128 * 128).map(|_| rng.below(k as u64) as u8).collect();
        let t = Mat::from_vec(128, k, rng.normal_vec_f32(128 * k));
        let x = Mat::from_vec(8, 128, rng.normal_vec_f32(8 * 128));
        let lut =
            ganq::quant::lut::lut_from_parts(128, 128, bits, codes, t);
        let want = lut.lut_matmul(&x);
        let out = rt
            .run(
                &name,
                &[
                    HostTensor::F32(vec![8, 128], x.data.clone()),
                    HostTensor::U8(vec![128, 64], lut.packed_nibbles()),
                    HostTensor::F32(
                        vec![128, k],
                        lut.codebook.data.clone(),
                    ),
                ],
            )
            .unwrap();
        let got = out[0].as_f32().unwrap();
        let maxdiff: f32 = got
            .iter()
            .zip(&want.data)
            .map(|(&a, &b)| (a - b).abs())
            .fold(0.0, f32::max);
        assert!(maxdiff < 1e-3, "{}: maxdiff {}", name, maxdiff);
    }
}

#[test]
fn resident_buffer_execution_matches_literal_execution() {
    // the execute_b (device-resident weights) fast path vs plain execute
    let rt = require!(runtime());
    let name = "lutgemm4_p8_128x128";
    if !rt.has_graph(name) {
        return;
    }
    let mut rng = Rng::new(3);
    let codes: Vec<u8> =
        (0..128 * 128).map(|_| rng.below(16) as u8).collect();
    let t = Mat::from_vec(128, 16, rng.normal_vec_f32(128 * 16));
    let x = Mat::from_vec(8, 128, rng.normal_vec_f32(8 * 128));
    let lut = ganq::quant::lut::lut_from_parts(128, 128, 4, codes, t);
    let inputs = [
        HostTensor::F32(vec![8, 128], x.data.clone()),
        HostTensor::U8(vec![128, 64], lut.packed_nibbles()),
        HostTensor::F32(vec![128, 16], lut.codebook.data.clone()),
    ];
    let via_lit = rt.run(name, &inputs).unwrap();
    let staged = rt.stage(&inputs[1..]).unwrap();
    let via_buf = rt
        .run_with_resident(name, &inputs[..1], &staged)
        .unwrap();
    assert_eq!(via_lit[0].as_f32().unwrap(), via_buf[0].as_f32().unwrap());
    // 5-D tensors (KV-cache shaped) must also stage cleanly
    let cache = HostTensor::F32(vec![2, 1, 2, 16, 8], vec![0.5; 512]);
    let b = rt.stage(&[cache]).unwrap();
    assert_eq!(b.len(), 1);
}

#[test]
fn ganq_hlo_graph_matches_native_solver() {
    let rt = require!(runtime());
    if !rt.has_graph("ganq4_64x64") {
        eprintln!("skipping: ganq4_64x64 not built");
        return;
    }
    let mut rng = Rng::new(11);
    let w = Mat::from_vec(64, 64, rng.normal_vec_f32(64 * 64));
    let x = Mat::from_vec(64, 160, rng.normal_vec_f32(64 * 160));
    let h = x.gram();
    let hlo = ganq_hlo::quantize_layer_hlo(&rt, &w, &h, 4)
        .unwrap()
        .expect("artifact exists");
    let native = ganq::quant::ganq::Ganq::new(4).quantize(&w, &h);
    let hp = linalg::precondition(&h);
    let e_hlo = linalg::layer_error(&w, &hlo.w_hat, &hp);
    let e_nat = linalg::layer_error(&w, &native.w_hat, &hp);
    // same algorithm, different float orders: quality must match closely
    assert!(
        (e_hlo - e_nat).abs() < 0.05 * e_nat.max(1e-9),
        "hlo {} vs native {}",
        e_hlo,
        e_nat
    );
    // and the HLO per-iteration errors must be monotone (Algorithm 1)
    let errs = ganq_hlo::solve_errors_hlo(&rt, &w, &h, 4)
        .unwrap()
        .unwrap();
    for win in errs.windows(2) {
        assert!(win[1] <= win[0] * 1.001 + 1e-4, "{:?}", errs);
    }
}

#[test]
fn nll_graph_matches_native_forward() {
    let rt = require!(runtime());
    let store = require!(store_for(&rt, "opt-micro"));
    if !rt.has_graph("nll_fp32_opt-micro") {
        return;
    }
    let f = corpus::flavor("wiki2s").unwrap();
    let mut eng_h = PplEngine::hlo(&rt, "opt-micro", &store, None).unwrap();
    let mut eng_n = PplEngine::native(Weights::Fp(&store));
    let ppl_h = eval::perplexity(&mut eng_h, f, Split::Valid, 1).unwrap();
    let ppl_n = eval::perplexity(&mut eng_n, f, Split::Valid, 1).unwrap();
    assert!(
        (ppl_h - ppl_n).abs() < 0.02 * ppl_n,
        "hlo ppl {} vs native {}",
        ppl_h,
        ppl_n
    );
}

#[test]
fn decode_graph_matches_native_decode() {
    let rt = require!(runtime());
    let store = require!(store_for(&rt, "opt-micro"));
    if !rt.has_graph("decode_fp32_opt-micro_b1") {
        return;
    }
    let prompt: Vec<i32> = b"the quick brown".iter().map(|&b| b as i32).collect();
    // native
    let w = Weights::Fp(&store);
    let mut be_n = coordinator::NativeBackend::new(w, 1);
    let reqs = vec![GenRequest::greedy(1, prompt.clone(), 8)];
    let (resp_n, _) = coordinator::serve(&mut be_n, reqs.clone()).unwrap();
    // hlo
    let mut be_h = coordinator::HloBackend::new(
        &rt,
        "opt-micro",
        WeightFmt::Fp32,
        1,
        &store,
        None,
        false,
    )
    .unwrap();
    let (resp_h, metrics) = coordinator::serve(&mut be_h, reqs).unwrap();
    assert_eq!(
        resp_n[0].tokens, resp_h[0].tokens,
        "HLO and native generation diverged"
    );
    assert!(metrics.decode_steps >= 8);
}

#[test]
fn pallas_decode_graph_matches_lut_decode_graph() {
    let rt = require!(runtime());
    let store = require!(store_for(&rt, "opt-micro"));
    if !rt.has_graph("decode_pallas4_opt-micro_b1")
        || !rt.has_graph("decode_lut4_opt-micro_b1")
    {
        return;
    }
    let calib = coordinator::calibrate(&store, 4, 64);
    let qm = coordinator::quantize_model(
        &store,
        "ganq",
        4,
        &calib,
        &QuantEngine::Native,
        false,
    )
    .unwrap();
    let prompt: Vec<i32> = b"lorem ipsum".iter().map(|&b| b as i32).collect();
    let reqs = vec![GenRequest::greedy(1, prompt, 6)];
    let mut outs = Vec::new();
    for graph_fmt in ["lut4", "pallas4"] {
        // HloBackend derives the graph name from WeightFmt; the pallas
        // variant shares the lut4 weight layout
        let mut be = coordinator::HloBackend::new(
            &rt,
            "opt-micro",
            WeightFmt::Lut4,
            1,
            &store,
            Some(&qm),
            false,
        )
        .unwrap();
        if graph_fmt == "pallas4" {
            // swap the graph name (same inputs/outputs signature)
            be = coordinator::HloBackend::new_with_graph(
                &rt,
                "opt-micro",
                "decode_pallas4_opt-micro_b1",
                1,
                &store,
                Some(&qm),
            )
            .unwrap();
        }
        let (resp, _) = coordinator::serve(&mut be, reqs.clone()).unwrap();
        outs.push(resp[0].tokens.clone());
    }
    assert_eq!(outs[0], outs[1], "pallas kernel path diverged from LUT path");
}

#[test]
fn lut_serving_matches_dequantized_eval() {
    // generation through the LUT decode graph == native generation with
    // the dequantized model (W_hat identical by construction)
    let rt = require!(runtime());
    let store = require!(store_for(&rt, "opt-small"));
    if !rt.has_graph("decode_lut4_opt-small_b1") {
        return;
    }
    let calib = coordinator::calibrate(&store, 8, 64);
    let qm = coordinator::quantize_model(
        &store,
        "ganq",
        4,
        &calib,
        &QuantEngine::Native,
        false,
    )
    .unwrap();
    let prompt: Vec<i32> = b"counting one two".iter().map(|&b| b as i32).collect();
    let reqs = vec![GenRequest::greedy(1, prompt, 10)];
    let mut be_h = coordinator::HloBackend::new(
        &rt,
        "opt-small",
        WeightFmt::Lut4,
        1,
        &store,
        Some(&qm),
        true, // resident weights: also covers the execute_b path
    )
    .unwrap();
    let (resp_h, _) = coordinator::serve(&mut be_h, reqs.clone()).unwrap();
    let w = Weights::Quant(&qm);
    let mut be_n = coordinator::NativeBackend::new(w, 1);
    let (resp_n, _) = coordinator::serve(&mut be_n, reqs).unwrap();
    assert_eq!(resp_h[0].tokens, resp_n[0].tokens);
}

#[test]
fn batched_decode_graph_consistent_with_b1() {
    let rt = require!(runtime());
    let store = require!(store_for(&rt, "opt-small"));
    if !rt.has_graph("decode_fp32_opt-small_b4") {
        return;
    }
    let mk = |id: u64, text: &str| {
        GenRequest::greedy(id, text.bytes().map(|b| b as i32).collect(), 5)
    };
    let reqs =
        vec![mk(1, "alpha beta"), mk(2, "gamma"), mk(3, "delta epsilon z")];
    let mut be4 = coordinator::HloBackend::new(
        &rt, "opt-small", WeightFmt::Fp32, 4, &store, None, false,
    )
    .unwrap();
    let (r4, _) = coordinator::serve(&mut be4, reqs.clone()).unwrap();
    let mut be1 = coordinator::HloBackend::new(
        &rt, "opt-small", WeightFmt::Fp32, 1, &store, None, false,
    )
    .unwrap();
    let (r1, _) = coordinator::serve(&mut be1, reqs).unwrap();
    for (a, b) in r4.iter().zip(&r1) {
        assert_eq!(a.id, b.id);
        assert_eq!(a.tokens, b.tokens, "req {} diverged across batch sizes", a.id);
    }
}

#[test]
fn ppl_ordering_full_vs_quant_on_trained_model() {
    // Table 2's shape at the smallest scale: FP16 <= GANQ-4bit <= GANQ-3bit
    // (perplexity, trained opt-micro)
    let rt = require!(runtime());
    let store = require!(store_for(&rt, "opt-micro"));
    if !rt.has_graph("nll_fp32_opt-micro") {
        return;
    }
    let f = corpus::flavor("wiki2s").unwrap();
    let calib = coordinator::calibrate(&store, 16, 128);
    let mut ppls = Vec::new();
    for bits in [16u8, 4, 3] {
        let qm = if bits == 16 {
            None
        } else {
            Some(
                coordinator::quantize_model(
                    &store,
                    "ganq",
                    bits,
                    &calib,
                    &QuantEngine::Native,
                    false,
                )
                .unwrap(),
            )
        };
        let mut eng =
            PplEngine::hlo(&rt, "opt-micro", &store, qm.as_ref()).unwrap();
        ppls.push(eval::perplexity(&mut eng, f, Split::Valid, 2).unwrap());
    }
    assert!(
        ppls[0] <= ppls[1] * 1.02 && ppls[1] <= ppls[2] * 1.02,
        "ppl ordering violated: fp {} / 4b {} / 3b {}",
        ppls[0],
        ppls[1],
        ppls[2]
    );
}

#[test]
fn model_config_from_manifest_matches_builtin() {
    let rt = require!(runtime());
    for (name, entry) in &rt.manifest.models {
        if let Some(b) = ModelConfig::builtin(name) {
            assert_eq!(entry.config, b, "config drift for {}", name);
        }
    }
}
