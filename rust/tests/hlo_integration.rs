//! HLO-path integration: every class of AOT artifact executed through the
//! PJRT runtime and cross-checked against the Rust-native implementation.
//! These are the tests proving the three layers compose. Skipped when
//! artifacts are absent.

use ganq::coordinator::{
    self, DecodeBackend, GenRequest, QuantEngine, SamplingParams,
    ServeOptions, SlotWork, StopCriteria, WeightFmt,
};
use ganq::data::corpus::{self, Split};
use ganq::eval::{self, PplEngine};
use ganq::model::forward::Weights;
use ganq::model::{ModelConfig, WeightStore};
use ganq::quant::Quantizer;
use ganq::runtime::{ganq_hlo, HostTensor, Runtime};
use ganq::tensor::{linalg, Mat};
use ganq::util::rng::Rng;

fn runtime() -> Option<Runtime> {
    match Runtime::load() {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("skipping HLO tests: {}", e);
            None
        }
    }
}

fn store_for(rt: &Runtime, model: &str) -> Option<WeightStore> {
    let cfg = rt.manifest.models.get(model)?.config;
    WeightStore::load(&rt.base, model, cfg).ok()
}

macro_rules! require {
    ($e:expr) => {
        match $e {
            Some(v) => v,
            None => return,
        }
    };
}

#[test]
fn lutgemm_kernel_artifact_matches_native() {
    let rt = require!(runtime());
    for bits in [4u8, 3] {
        let name = format!("lutgemm{}_p8_128x128", bits);
        if !rt.has_graph(&name) {
            eprintln!("skipping: {} not built", name);
            continue;
        }
        let mut rng = Rng::new(7);
        let k = 1usize << bits;
        let codes: Vec<u8> =
            (0..128 * 128).map(|_| rng.below(k as u64) as u8).collect();
        let t = Mat::from_vec(128, k, rng.normal_vec_f32(128 * k));
        let x = Mat::from_vec(8, 128, rng.normal_vec_f32(8 * 128));
        let lut =
            ganq::quant::lut::lut_from_parts(128, 128, bits, codes, t);
        let want = lut.lut_matmul(&x);
        let out = rt
            .run(
                &name,
                &[
                    HostTensor::F32(vec![8, 128], x.data.clone()),
                    HostTensor::U8(vec![128, 64], lut.packed_nibbles()),
                    HostTensor::F32(
                        vec![128, k],
                        lut.codebook.data.clone(),
                    ),
                ],
            )
            .unwrap();
        let got = out[0].as_f32().unwrap();
        let maxdiff: f32 = got
            .iter()
            .zip(&want.data)
            .map(|(&a, &b)| (a - b).abs())
            .fold(0.0, f32::max);
        assert!(maxdiff < 1e-3, "{}: maxdiff {}", name, maxdiff);
    }
}

#[test]
fn resident_buffer_execution_matches_literal_execution() {
    // the execute_b (device-resident weights) fast path vs plain execute
    let rt = require!(runtime());
    let name = "lutgemm4_p8_128x128";
    if !rt.has_graph(name) {
        return;
    }
    let mut rng = Rng::new(3);
    let codes: Vec<u8> =
        (0..128 * 128).map(|_| rng.below(16) as u8).collect();
    let t = Mat::from_vec(128, 16, rng.normal_vec_f32(128 * 16));
    let x = Mat::from_vec(8, 128, rng.normal_vec_f32(8 * 128));
    let lut = ganq::quant::lut::lut_from_parts(128, 128, 4, codes, t);
    let inputs = [
        HostTensor::F32(vec![8, 128], x.data.clone()),
        HostTensor::U8(vec![128, 64], lut.packed_nibbles()),
        HostTensor::F32(vec![128, 16], lut.codebook.data.clone()),
    ];
    let via_lit = rt.run(name, &inputs).unwrap();
    let staged = rt.stage(&inputs[1..]).unwrap();
    let via_buf = rt
        .run_with_resident(name, &inputs[..1], &staged)
        .unwrap();
    assert_eq!(via_lit[0].as_f32().unwrap(), via_buf[0].as_f32().unwrap());
    // 5-D tensors (KV-cache shaped) must also stage cleanly
    let cache = HostTensor::F32(vec![2, 1, 2, 16, 8], vec![0.5; 512]);
    let b = rt.stage(&[cache]).unwrap();
    assert_eq!(b.len(), 1);
}

#[test]
fn ganq_hlo_graph_matches_native_solver() {
    let rt = require!(runtime());
    if !rt.has_graph("ganq4_64x64") {
        eprintln!("skipping: ganq4_64x64 not built");
        return;
    }
    let mut rng = Rng::new(11);
    let w = Mat::from_vec(64, 64, rng.normal_vec_f32(64 * 64));
    let x = Mat::from_vec(64, 160, rng.normal_vec_f32(64 * 160));
    let h = x.gram();
    let hlo = ganq_hlo::quantize_layer_hlo(&rt, &w, &h, 4)
        .unwrap()
        .expect("artifact exists");
    let native = ganq::quant::ganq::Ganq::new(4).quantize(&w, &h);
    let hp = linalg::precondition(&h);
    let e_hlo = linalg::layer_error(&w, &hlo.w_hat, &hp);
    let e_nat = linalg::layer_error(&w, &native.w_hat, &hp);
    // same algorithm, different float orders: quality must match closely
    assert!(
        (e_hlo - e_nat).abs() < 0.05 * e_nat.max(1e-9),
        "hlo {} vs native {}",
        e_hlo,
        e_nat
    );
    // and the HLO per-iteration errors must be monotone (Algorithm 1)
    let errs = ganq_hlo::solve_errors_hlo(&rt, &w, &h, 4)
        .unwrap()
        .unwrap();
    for win in errs.windows(2) {
        assert!(win[1] <= win[0] * 1.001 + 1e-4, "{:?}", errs);
    }
}

#[test]
fn nll_graph_matches_native_forward() {
    let rt = require!(runtime());
    let store = require!(store_for(&rt, "opt-micro"));
    if !rt.has_graph("nll_fp32_opt-micro") {
        return;
    }
    let f = corpus::flavor("wiki2s").unwrap();
    let mut eng_h = PplEngine::hlo(&rt, "opt-micro", &store, None).unwrap();
    let mut eng_n = PplEngine::native(Weights::Fp(&store));
    let ppl_h = eval::perplexity(&mut eng_h, f, Split::Valid, 1).unwrap();
    let ppl_n = eval::perplexity(&mut eng_n, f, Split::Valid, 1).unwrap();
    assert!(
        (ppl_h - ppl_n).abs() < 0.02 * ppl_n,
        "hlo ppl {} vs native {}",
        ppl_h,
        ppl_n
    );
}

#[test]
fn decode_graph_matches_native_decode() {
    let rt = require!(runtime());
    let store = require!(store_for(&rt, "opt-micro"));
    if !rt.has_graph("decode_fp32_opt-micro_b1") {
        return;
    }
    let prompt: Vec<i32> = b"the quick brown".iter().map(|&b| b as i32).collect();
    // native
    let w = Weights::Fp(&store);
    let mut be_n = coordinator::NativeBackend::new(w, 1);
    let reqs = vec![GenRequest::greedy(1, prompt.clone(), 8)];
    let (resp_n, _) = coordinator::serve(&mut be_n, reqs.clone()).unwrap();
    // hlo
    let mut be_h = coordinator::HloBackend::new(
        &rt,
        "opt-micro",
        WeightFmt::Fp32,
        1,
        &store,
        None,
        false,
    )
    .unwrap();
    let (resp_h, metrics) = coordinator::serve(&mut be_h, reqs).unwrap();
    assert_eq!(
        resp_n[0].tokens, resp_h[0].tokens,
        "HLO and native generation diverged"
    );
    assert!(metrics.decode_steps >= 8);
}

/// Drive one slot's prompt through `be.step` in runs of `chunk` tokens
/// (`usize::MAX` = the whole prompt in one step — the backend's internal
/// multi-dispatch path; `1` = the per-token decode-graph fallback) and
/// return the final prompt position's logits row.
fn prefill_logits(
    be: &mut dyn DecodeBackend,
    prompt: &[i32],
    chunk: usize,
) -> Vec<f32> {
    be.reset_slot(0);
    let mut out = Vec::new();
    let mut i = 0;
    while i < prompt.len() {
        let take = chunk.min(prompt.len() - i);
        let want = i + take == prompt.len();
        let logits = be
            .step(&[SlotWork {
                slot: 0,
                tokens: prompt[i..i + take].to_vec(),
                want_logits: want,
            }])
            .unwrap();
        if want {
            out = logits.into_iter().next().unwrap();
        }
        i += take;
    }
    out
}

#[test]
fn hlo_chunked_prefill_matches_per_token_fp32() {
    // The acceptance parity bar across ragged prompt lengths (padded
    // tails included), in decreasing strictness:
    //  * re-running the same chunking is BITWISE identical (one
    //    compiled executable is deterministic run to run);
    //  * different chunk sizes — and the backend's multi-dispatch
    //    bucketing — agree within 1e-5 (in practice they are bitwise
    //    on XLA CPU, measured via jit in python; the assert leaves
    //    reassociation headroom because differently shaped compiled
    //    graphs carry no bitwise guarantee);
    //  * the per-token decode-graph path agrees within 1e-3 with the
    //    same argmax.
    let rt = require!(runtime());
    let store = require!(store_for(&rt, "opt-mini"));
    if rt.manifest.prefill_chunks("fp32", "opt-mini", 1).is_empty() {
        eprintln!("skipping: no fp32 opt-mini prefill graphs");
        return;
    }
    let mut be = coordinator::HloBackend::new(
        &rt, "opt-mini", WeightFmt::Fp32, 1, &store, None, false,
    )
    .unwrap();
    assert!(be.max_chunk() >= 8, "compiled chunks drive max_chunk");
    let spread = |a: &[f32], b: &[f32]| -> f32 {
        a.iter()
            .zip(b)
            .map(|(&x, &y)| (x - y).abs())
            .fold(0.0, f32::max)
    };
    for plen in [5usize, 13, 31, 32, 37, 64] {
        let prompt: Vec<i32> =
            (0..plen as i32).map(|i| (i * 31 + 7) % 256).collect();
        let per_token = prefill_logits(&mut be, &prompt, 1);
        let again = prefill_logits(&mut be, &prompt, 8);
        let chunked: Vec<Vec<f32>> = [8, 16, 32, usize::MAX]
            .iter()
            .map(|&c| prefill_logits(&mut be, &prompt, c))
            .collect();
        assert_eq!(
            again, chunked[0],
            "plen {}: same chunking must be bitwise deterministic",
            plen
        );
        for (ci, lg) in chunked.iter().enumerate() {
            assert!(
                spread(lg, &chunked[0]) < 1e-5,
                "plen {}: chunk variant {} diverged",
                plen,
                ci
            );
        }
        assert!(
            spread(&per_token, &chunked[0]) < 1e-3,
            "plen {}: chunked vs per-token maxdiff {}",
            plen,
            spread(&per_token, &chunked[0])
        );
        let am = |v: &[f32]| {
            v.iter()
                .enumerate()
                .max_by(|a, b| a.1.total_cmp(b.1))
                .unwrap()
                .0
        };
        assert_eq!(am(&per_token), am(&chunked[0]), "plen {}", plen);
    }
}

#[test]
fn hlo_chunked_prefill_lut_within_tolerance() {
    let rt = require!(runtime());
    let store = require!(store_for(&rt, "opt-mini"));
    if rt.manifest.prefill_chunks("lut4", "opt-mini", 1).is_empty() {
        eprintln!("skipping: no lut4 opt-mini prefill graphs");
        return;
    }
    let calib = coordinator::calibrate(&store, 4, 64);
    let qm = coordinator::quantize_model(
        &store,
        "ganq",
        4,
        &calib,
        &QuantEngine::Native,
        false,
    )
    .unwrap();
    let mut be = coordinator::HloBackend::new(
        &rt,
        "opt-mini",
        WeightFmt::Lut4,
        1,
        &store,
        Some(&qm),
        false,
    )
    .unwrap();
    for plen in [9usize, 24, 40] {
        let prompt: Vec<i32> =
            (0..plen as i32).map(|i| (i * 17 + 3) % 256).collect();
        let per_token = prefill_logits(&mut be, &prompt, 1);
        let chunked = prefill_logits(&mut be, &prompt, usize::MAX);
        let maxdiff: f32 = per_token
            .iter()
            .zip(&chunked)
            .map(|(&a, &b)| (a - b).abs())
            .fold(0.0, f32::max);
        assert!(maxdiff < 1e-3, "plen {}: maxdiff {}", plen, maxdiff);
    }
}

#[test]
fn hlo_chunked_prefill_serving_matches_per_token_serving() {
    // mixed prefill + decode batches through the real scheduler: ragged
    // prompts at b=4 admit staggered, so prefill chunks and decode
    // positions share steps; greedy outputs must be identical to the
    // per-token (prefill_chunk = 1, decode-graph-only) run — and TTFT
    // work should shrink to fewer scheduler steps
    let rt = require!(runtime());
    let store = require!(store_for(&rt, "opt-small"));
    if rt.manifest.prefill_chunks("fp32", "opt-small", 4).is_empty() {
        eprintln!("skipping: no fp32 opt-small b4 prefill graphs");
        return;
    }
    let reqs: Vec<GenRequest> = (0..5)
        .map(|i| {
            GenRequest::greedy(
                i,
                (0..21 + 9 * i as i32)
                    .map(|j| (j * 13 + i as i32) % 256)
                    .collect(),
                6,
            )
        })
        .collect();
    let serve_chunk = |chunk: usize| {
        let mut be = coordinator::HloBackend::new(
            &rt, "opt-small", WeightFmt::Fp32, 4, &store, None, false,
        )
        .unwrap();
        coordinator::serve_with(
            &mut be,
            reqs.clone(),
            ServeOptions { prefill_chunk: chunk, ..ServeOptions::default() },
        )
        .unwrap()
    };
    let (resp_1, m_1) = serve_chunk(1);
    let (resp_c, m_c) = serve_chunk(128);
    for (a, b) in resp_1.iter().zip(&resp_c) {
        assert_eq!(a.id, b.id);
        assert_eq!(a.tokens, b.tokens, "req {} diverged", a.id);
    }
    assert!(
        m_c.decode_steps < m_1.decode_steps,
        "chunked prefill must take fewer steps ({} vs {})",
        m_c.decode_steps,
        m_1.decode_steps
    );
    assert_eq!(m_c.prompt_positions, m_1.prompt_positions);
}

#[test]
fn hlo_sampling_deterministic_across_chunk_sizes() {
    // sampled serving is a pure function of (seed, draw index), so HLO
    // chunk size — like every other batching knob — must not change
    // sampled outputs
    let rt = require!(runtime());
    let store = require!(store_for(&rt, "opt-mini"));
    if rt.manifest.prefill_chunks("fp32", "opt-mini", 1).is_empty() {
        eprintln!("skipping: no fp32 opt-mini prefill graphs");
        return;
    }
    let mk_reqs = || -> Vec<GenRequest> {
        (0..2)
            .map(|i| {
                GenRequest::new(
                    i,
                    (0..26 + 7 * i as i32).map(|j| (j * 11) % 256).collect(),
                    SamplingParams::sample(0.8, 42 + i).with_top_k(40),
                    StopCriteria::max_tokens(8),
                )
            })
            .collect()
    };
    let mut outs = Vec::new();
    for chunk in [1usize, 8, 32] {
        let mut be = coordinator::HloBackend::new(
            &rt, "opt-mini", WeightFmt::Fp32, 1, &store, None, false,
        )
        .unwrap();
        let (resp, _) = coordinator::serve_with(
            &mut be,
            mk_reqs(),
            ServeOptions { prefill_chunk: chunk, ..ServeOptions::default() },
        )
        .unwrap();
        outs.push(resp);
    }
    for resp in &outs[1..] {
        for (a, b) in outs[0].iter().zip(resp) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.tokens, b.tokens, "req {} diverged", a.id);
        }
    }
}

#[test]
fn pallas_decode_graph_matches_lut_decode_graph() {
    let rt = require!(runtime());
    let store = require!(store_for(&rt, "opt-micro"));
    if !rt.has_graph("decode_pallas4_opt-micro_b1")
        || !rt.has_graph("decode_lut4_opt-micro_b1")
    {
        return;
    }
    let calib = coordinator::calibrate(&store, 4, 64);
    let qm = coordinator::quantize_model(
        &store,
        "ganq",
        4,
        &calib,
        &QuantEngine::Native,
        false,
    )
    .unwrap();
    let prompt: Vec<i32> = b"lorem ipsum".iter().map(|&b| b as i32).collect();
    let reqs = vec![GenRequest::greedy(1, prompt, 6)];
    let mut outs = Vec::new();
    for graph_fmt in ["lut4", "pallas4"] {
        // HloBackend derives the graph name from WeightFmt; the pallas
        // variant shares the lut4 weight layout
        let mut be = coordinator::HloBackend::new(
            &rt,
            "opt-micro",
            WeightFmt::Lut4,
            1,
            &store,
            Some(&qm),
            false,
        )
        .unwrap();
        if graph_fmt == "pallas4" {
            // swap the graph name (same inputs/outputs signature)
            be = coordinator::HloBackend::new_with_graph(
                &rt,
                "opt-micro",
                "decode_pallas4_opt-micro_b1",
                1,
                &store,
                Some(&qm),
            )
            .unwrap();
        }
        let (resp, _) = coordinator::serve(&mut be, reqs.clone()).unwrap();
        outs.push(resp[0].tokens.clone());
    }
    assert_eq!(outs[0], outs[1], "pallas kernel path diverged from LUT path");
}

#[test]
fn lut_serving_matches_dequantized_eval() {
    // generation through the LUT decode graph == native generation with
    // the dequantized model (W_hat identical by construction)
    let rt = require!(runtime());
    let store = require!(store_for(&rt, "opt-small"));
    if !rt.has_graph("decode_lut4_opt-small_b1") {
        return;
    }
    let calib = coordinator::calibrate(&store, 8, 64);
    let qm = coordinator::quantize_model(
        &store,
        "ganq",
        4,
        &calib,
        &QuantEngine::Native,
        false,
    )
    .unwrap();
    let prompt: Vec<i32> = b"counting one two".iter().map(|&b| b as i32).collect();
    let reqs = vec![GenRequest::greedy(1, prompt, 10)];
    let mut be_h = coordinator::HloBackend::new(
        &rt,
        "opt-small",
        WeightFmt::Lut4,
        1,
        &store,
        Some(&qm),
        true, // resident weights: also covers the execute_b path
    )
    .unwrap();
    let (resp_h, _) = coordinator::serve(&mut be_h, reqs.clone()).unwrap();
    let w = Weights::Quant(&qm);
    let mut be_n = coordinator::NativeBackend::new(w, 1);
    let (resp_n, _) = coordinator::serve(&mut be_n, reqs).unwrap();
    assert_eq!(resp_h[0].tokens, resp_n[0].tokens);
}

#[test]
fn batched_decode_graph_consistent_with_b1() {
    let rt = require!(runtime());
    let store = require!(store_for(&rt, "opt-small"));
    if !rt.has_graph("decode_fp32_opt-small_b4") {
        return;
    }
    let mk = |id: u64, text: &str| {
        GenRequest::greedy(id, text.bytes().map(|b| b as i32).collect(), 5)
    };
    let reqs =
        vec![mk(1, "alpha beta"), mk(2, "gamma"), mk(3, "delta epsilon z")];
    let mut be4 = coordinator::HloBackend::new(
        &rt, "opt-small", WeightFmt::Fp32, 4, &store, None, false,
    )
    .unwrap();
    let (r4, _) = coordinator::serve(&mut be4, reqs.clone()).unwrap();
    let mut be1 = coordinator::HloBackend::new(
        &rt, "opt-small", WeightFmt::Fp32, 1, &store, None, false,
    )
    .unwrap();
    let (r1, _) = coordinator::serve(&mut be1, reqs).unwrap();
    for (a, b) in r4.iter().zip(&r1) {
        assert_eq!(a.id, b.id);
        assert_eq!(a.tokens, b.tokens, "req {} diverged across batch sizes", a.id);
    }
}

#[test]
fn ppl_ordering_full_vs_quant_on_trained_model() {
    // Table 2's shape at the smallest scale: FP16 <= GANQ-4bit <= GANQ-3bit
    // (perplexity, trained opt-micro)
    let rt = require!(runtime());
    let store = require!(store_for(&rt, "opt-micro"));
    if !rt.has_graph("nll_fp32_opt-micro") {
        return;
    }
    let f = corpus::flavor("wiki2s").unwrap();
    let calib = coordinator::calibrate(&store, 16, 128);
    let mut ppls = Vec::new();
    for bits in [16u8, 4, 3] {
        let qm = if bits == 16 {
            None
        } else {
            Some(
                coordinator::quantize_model(
                    &store,
                    "ganq",
                    bits,
                    &calib,
                    &QuantEngine::Native,
                    false,
                )
                .unwrap(),
            )
        };
        let mut eng =
            PplEngine::hlo(&rt, "opt-micro", &store, qm.as_ref()).unwrap();
        ppls.push(eval::perplexity(&mut eng, f, Split::Valid, 2).unwrap());
    }
    assert!(
        ppls[0] <= ppls[1] * 1.02 && ppls[1] <= ppls[2] * 1.02,
        "ppl ordering violated: fp {} / 4b {} / 3b {}",
        ppls[0],
        ppls[1],
        ppls[2]
    );
}

#[test]
fn model_config_from_manifest_matches_builtin() {
    let rt = require!(runtime());
    for (name, entry) in &rt.manifest.models {
        if let Some(b) = ModelConfig::builtin(name) {
            assert_eq!(entry.config, b, "config drift for {}", name);
        }
    }
}
