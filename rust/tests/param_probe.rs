//! Parameterized probe runner: executes /tmp/probe.hlo.txt with the inputs
//! in /tmp/probe.json and compares q against the jax-computed expectation.
//! Used with python/tools gen_probe.py to bisect the size-dependent
//! S-step miscompilation on xla_extension 0.5.1.

use ganq::util::json::Json;

#[test]
fn param_probe() {
    let (Ok(hlo), Ok(meta)) = (
        std::fs::read_to_string("/tmp/probe.hlo.txt"),
        std::fs::read_to_string("/tmp/probe.json"),
    ) else {
        eprintln!("skipping: no probe files");
        return;
    };
    let _ = hlo;
    let j = Json::parse(&meta).unwrap();
    let m = j.get("m").unwrap().as_usize().unwrap();
    let n = j.get("n").unwrap().as_usize().unwrap();
    let k = j.get("k").unwrap().as_usize().unwrap();
    let w = j.get("w").unwrap().as_f32_vec().unwrap();
    let l = j.get("l").unwrap().as_f32_vec().unwrap();
    let t0 = j.get("t0").unwrap().as_f32_vec().unwrap();
    let expect: Vec<i32> = j
        .get("q")
        .unwrap()
        .as_f32_vec()
        .unwrap()
        .iter()
        .map(|&v| v as i32)
        .collect();

    let client = xla::PjRtClient::cpu().unwrap();
    let proto =
        xla::HloModuleProto::from_text_file("/tmp/probe.hlo.txt").unwrap();
    let comp = xla::XlaComputation::from_proto(&proto);
    let exe = client.compile(&comp).unwrap();
    let args = [
        xla::Literal::vec1(&w).reshape(&[m as i64, n as i64]).unwrap(),
        xla::Literal::vec1(&l).reshape(&[n as i64, n as i64]).unwrap(),
        xla::Literal::vec1(&t0).reshape(&[m as i64, k as i64]).unwrap(),
    ];
    let out = exe.execute::<xla::Literal>(&args).unwrap()[0][0]
        .to_literal_sync()
        .unwrap();
    let parts = out.to_tuple().unwrap();
    let q = parts[0].to_vec::<i32>().unwrap();
    let mismatch = q.iter().zip(&expect).filter(|(a, b)| a != b).count();
    eprintln!(
        "m={} n={} k={}: {}/{} mismatches",
        m,
        n,
        k,
        mismatch,
        q.len()
    );
    assert_eq!(mismatch, 0, "old-XLA output diverges from jax");
}
