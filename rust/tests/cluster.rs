//! Chaos matrix for the multi-replica cluster (`coordinator::cluster`):
//! deterministic fault injection (kill / stall / shared-prefix kill)
//! against real replicas, asserting the robustness contract end to end:
//!
//! * every request reaches a terminal [`FinishReason`] — nothing hangs,
//!   nothing is lost, even when a replica dies mid-decode;
//! * retried requests produce **token-identical** output to an
//!   unfaulted single-backend reference run (sampling is pure in
//!   `(seed, draw index)`, so a replay on a survivor regenerates the
//!   same stream and the router's de-duplication splices it seamlessly);
//! * no stream sees a second `Done` (at-most-once delivery).

use std::collections::HashMap;
use std::sync::mpsc::Receiver;
use std::sync::Arc;
use std::time::{Duration, Instant};

use ganq::coordinator::{
    quiet_ganq_thread_panics, serve, Cluster, ClusterOptions, Fault,
    FaultPlan, FinishReason, GenOutcome, GenRequest, NativeBackend,
    ReplicaEngine, RoundCtx, SamplingParams, ServeMetrics, StopCriteria,
    TokenEvent,
};
use ganq::model::forward::Weights;
use ganq::model::{ModelConfig, WeightStore};

const DRAIN_TIMEOUT: Duration = Duration::from_secs(60);

fn shared_store(seed: u64) -> Arc<WeightStore> {
    let cfg = ModelConfig::builtin("opt-micro").unwrap();
    Arc::new(WeightStore::random("chaos", cfg, seed))
}

/// One replica = a fresh native backend per round over the shared
/// weights (the same inversion the threaded server uses).
struct NativeReplica {
    store: Arc<WeightStore>,
    slots: usize,
}

impl ReplicaEngine for NativeReplica {
    fn run(&mut self, round: RoundCtx<'_>) -> Result<ServeMetrics, String> {
        let w = Weights::Fp(&self.store);
        let mut be = NativeBackend::new(w, self.slots);
        round.run(&mut be)
    }
}

fn replicas(store: &Arc<WeightStore>, n: usize, slots: usize) -> Vec<NativeReplica> {
    (0..n)
        .map(|_| NativeReplica { store: Arc::clone(store), slots })
        .collect()
}

/// The test workload: request 1 samples (temperature 0.8, fixed seed)
/// so replay-after-retry exercises the sampler's determinism; the rest
/// are greedy.
fn make_requests(prompts: &[Vec<i32>], max_new: usize) -> Vec<GenRequest> {
    prompts
        .iter()
        .enumerate()
        .map(|(i, p)| {
            let id = i as u64 + 1;
            if i == 0 {
                GenRequest::new(
                    id,
                    p.clone(),
                    SamplingParams::sample(0.8, 42),
                    StopCriteria::max_tokens(max_new),
                )
            } else {
                GenRequest::greedy(id, p.clone(), max_new)
            }
        })
        .collect()
}

/// Unfaulted single-backend reference: batch composition differs from
/// any cluster run, but per-request outputs must not.
fn reference(
    store: &WeightStore,
    reqs: Vec<GenRequest>,
    slots: usize,
) -> HashMap<u64, GenOutcome> {
    let w = Weights::Fp(store);
    let mut be = NativeBackend::new(w, slots);
    let (outs, _m) = serve(&mut be, reqs).unwrap();
    outs.into_iter().map(|o| (o.id, o)).collect()
}

/// Drain one client stream: the streamed tokens, the single Done, and
/// proof the channel closed right after it (no second Done possible).
fn drain(rx: &Receiver<TokenEvent>) -> (Vec<i32>, GenOutcome) {
    let deadline = Instant::now() + DRAIN_TIMEOUT;
    let mut toks = Vec::new();
    loop {
        let left = deadline.saturating_duration_since(Instant::now());
        match rx.recv_timeout(left) {
            Ok(TokenEvent::Token { tok, .. }) => toks.push(tok),
            Ok(TokenEvent::Done(o)) => {
                assert!(
                    rx.recv().is_err(),
                    "stream must close after its Done (at-most-once)"
                );
                return (toks, o);
            }
            Err(e) => panic!("stream ended without a Done: {:?}", e),
        }
    }
}

/// Run `prompts` through a cluster under `plan` and check every request
/// against the unfaulted reference. Returns the cluster rollup for
/// fault-specific assertions.
fn run_and_verify(
    n_replicas: usize,
    slots: usize,
    prompts: &[Vec<i32>],
    max_new: usize,
    opts: ClusterOptions,
    plan: &FaultPlan,
) -> ganq::coordinator::ClusterMetrics {
    quiet_ganq_thread_panics();
    let store = shared_store(29);
    let want = reference(&store, make_requests(prompts, max_new), slots);

    let cluster = Cluster::spawn(replicas(&store, n_replicas, slots), opts, plan);
    let streams: Vec<(u64, Receiver<TokenEvent>)> =
        make_requests(prompts, max_new)
            .into_iter()
            .map(|req| {
                let id = req.id;
                (id, cluster.submit_request(req).0)
            })
            .collect();
    for (id, rx) in &streams {
        let (toks, o) = drain(rx);
        assert_eq!(o.id, *id);
        assert_eq!(
            toks, o.tokens,
            "req {}: streamed tokens must match the outcome exactly \
             (replay de-dup must not duplicate or drop)",
            id
        );
        let r = &want[id];
        assert_eq!(
            o.finish, r.finish,
            "req {}: finish reason differs from unfaulted reference",
            id
        );
        assert_eq!(
            o.tokens, r.tokens,
            "req {}: retried output must be token-identical to the \
             unfaulted reference run",
            id
        );
    }
    cluster.shutdown()
}

fn distinct_prompts(n: usize, len: usize) -> Vec<Vec<i32>> {
    (0..n)
        .map(|i| (0..len).map(|j| (i * 31 + j * 7 + 3) as i32 % 100).collect())
        .collect()
}

#[test]
fn kill_one_replica_mid_decode_loses_nothing() {
    let opts = ClusterOptions {
        backoff_ms: 0, // retry instantly; the kill is the point
        ..ClusterOptions::default()
    };
    let plan =
        FaultPlan::none().with(Fault::Kill { worker: 1, step: 10 });
    let cm = run_and_verify(2, 4, &distinct_prompts(6, 4), 24, opts, &plan);
    assert_eq!(cm.workers_died, 1, "{}", cm.summary());
    assert!(cm.requeues >= 1, "{}", cm.summary());
    assert_eq!(cm.replicas_alive(), 1);
    assert!(
        cm.replicas[1].fail_reason.as_deref().unwrap_or("").contains("kill"),
        "worker 1 should record the injected kill: {:?}",
        cm.replicas[1].fail_reason
    );
}

#[test]
fn stall_below_timeout_recovers_without_failover() {
    // 50ms hiccup vs the default 10s stall timeout: the worker is slow,
    // not dead — nothing requeues, outputs unchanged
    let plan =
        FaultPlan::none().with(Fault::Stall { worker: 0, step: 2, ms: 50 });
    let cm = run_and_verify(
        2,
        4,
        &distinct_prompts(4, 4),
        12,
        ClusterOptions::default(),
        &plan,
    );
    assert_eq!(cm.workers_died, 0, "{}", cm.summary());
    assert_eq!(cm.requeues, 0, "{}", cm.summary());
    assert_eq!(cm.replicas_alive(), 2);
}

#[test]
fn stalled_worker_is_detected_and_its_requests_requeue() {
    // 400ms wedge vs a 50ms stall timeout: the router declares worker 0
    // down mid-sleep and reroutes. The zombie wakes and finishes its
    // round; its stale events must be filtered (streams still see
    // exactly one Done, tokens identical to the reference).
    let opts = ClusterOptions {
        stall_timeout_ms: 50,
        backoff_ms: 0,
        ..ClusterOptions::default()
    };
    let plan = FaultPlan::none()
        .with(Fault::Stall { worker: 0, step: 3, ms: 400 });
    let cm = run_and_verify(2, 4, &distinct_prompts(4, 4), 16, opts, &plan);
    assert_eq!(cm.workers_died, 1, "{}", cm.summary());
    assert!(cm.requeues >= 1, "{}", cm.summary());
    assert!(
        cm.replicas[0]
            .fail_reason
            .as_deref()
            .unwrap_or("")
            .contains("stalled"),
        "worker 0 should be marked down as stalled: {:?}",
        cm.replicas[0].fail_reason
    );
}

#[test]
fn kill_under_shared_prefix_traffic_fails_over() {
    // all six requests share a 32-token prefix: affinity concentrates
    // them on one replica (the first pick), which then dies — the
    // survivor must absorb and reproduce every output
    let prefix: Vec<i32> = (0..32).map(|j| (j * 5 + 1) % 90).collect();
    let prompts: Vec<Vec<i32>> = (0..6)
        .map(|i| {
            let mut p = prefix.clone();
            p.push(90 + i);
            p
        })
        .collect();
    let opts = ClusterOptions {
        affinity_block: 16,
        backoff_ms: 0,
        ..ClusterOptions::default()
    };
    let plan = FaultPlan::none().with(Fault::Kill { worker: 0, step: 8 });
    let cm = run_and_verify(2, 4, &prompts, 16, opts, &plan);
    assert!(
        cm.affinity_hits >= 1,
        "shared-prefix requests must route by affinity: {}",
        cm.summary()
    );
    assert_eq!(cm.workers_died, 1, "{}", cm.summary());
    assert!(cm.requeues >= 1, "{}", cm.summary());
}

#[test]
fn single_replica_kill_rejects_cleanly_instead_of_hanging() {
    // no survivors: requests must still reach a terminal outcome
    // (Rejected) — the cluster fails fast rather than queueing forever
    quiet_ganq_thread_panics();
    let store = shared_store(31);
    let opts = ClusterOptions {
        backoff_ms: 0,
        max_retries: 1,
        ..ClusterOptions::default()
    };
    let plan = FaultPlan::none().with(Fault::Kill { worker: 0, step: 2 });
    let cluster = Cluster::spawn(replicas(&store, 1, 4), opts, &plan);
    let streams: Vec<Receiver<TokenEvent>> = distinct_prompts(3, 4)
        .iter()
        .enumerate()
        .map(|(i, p)| {
            cluster
                .submit_request(GenRequest::greedy(i as u64 + 1, p.clone(), 24))
                .0
        })
        .collect();
    for rx in &streams {
        let (_toks, o) = drain(rx);
        assert_eq!(o.finish, FinishReason::Rejected);
    }
    let cm = cluster.shutdown();
    assert_eq!(cm.workers_died, 1, "{}", cm.summary());
    assert_eq!(cm.replicas_alive(), 0);
}

#[test]
fn deadline_propagates_through_the_cluster() {
    // an already-expired deadline ends DeadlineExceeded (empty output)
    // while a normal request on the same cluster completes untouched
    let store = shared_store(37);
    let cluster = Cluster::spawn(
        replicas(&store, 1, 2),
        ClusterOptions::default(),
        &FaultPlan::none(),
    );
    let doomed = GenRequest::greedy(1, vec![5, 6, 7], 8).with_deadline_ms(0.0);
    let (rx_doomed, _) = cluster.submit_request(doomed);
    let (rx_ok, _) =
        cluster.submit_request(GenRequest::greedy(2, vec![8, 9, 10], 8));
    let (toks, o) = drain(&rx_doomed);
    assert_eq!(o.finish, FinishReason::DeadlineExceeded);
    assert!(toks.is_empty() && o.tokens.is_empty());
    let (_t, ok) = drain(&rx_ok);
    assert_eq!(ok.finish, FinishReason::MaxTokens);
    assert_eq!(ok.tokens.len(), 8);
    let cm = cluster.shutdown();
    assert_eq!(cm.total.finish.deadline, 1, "{}", cm.total.summary());
}
