//! Paged KV-cache integration tests: bit-exactness of the F32 block
//! store against the contiguous cache, tolerance of the LUT block store,
//! the admission-capacity win of paging + prefix sharing at a fixed KV
//! memory budget, and the chunked-prefill property suite — chunked
//! prefill must be bitwise-identical to per-token prefill on dense KV
//! (within 1e-3 for LUT block stores) across chunk sizes, ragged
//! prompts, and prefix-skip resumes.

use ganq::coordinator::{
    serve, GenRequest, KvStoreKind, NativeBackend, PagedNativeBackend,
};
use ganq::kv::{F32Blocks, KvLayout, LutBlocks, PagedKv};
use ganq::model::forward::{
    Engine, KvCache, KvSeq, LogitsMode, SeqRefs, StepItem, StepPlan, Weights,
};
use ganq::model::{ModelConfig, WeightStore};
use ganq::util::prop;

fn micro_store(seed: u64) -> WeightStore {
    let cfg = ModelConfig::builtin("opt-micro").unwrap();
    WeightStore::random("t", cfg, seed)
}

/// One single-position step for one sequence (per-token reference).
fn decode_one(engine: &mut Engine, tok: i32, cache: &mut dyn KvSeq) -> Vec<f32> {
    let mut refs: Vec<&mut dyn KvSeq> = vec![cache];
    engine
        .decode_batch(&[tok], &mut SeqRefs(&mut refs))
        .into_iter()
        .next()
        .unwrap()
}

/// Decode `seq` through a fresh PagedKv slot token-by-token, returning
/// per-step logits. `resume_from` positions are assumed cached (prefix
/// hit) and skipped.
fn paged_decode(
    kv: &mut PagedKv,
    engine: &mut Engine,
    slot: usize,
    seq: &[i32],
    resume_from: usize,
) -> Vec<Vec<f32>> {
    let mut out = Vec::new();
    for &t in &seq[resume_from..] {
        let mut active = vec![false; kv.num_slots()];
        active[slot] = true;
        assert!(kv.prepare_step(&active).is_empty(), "no preemption");
        kv.push_token(slot, t);
        let mut view = kv.slot_view(slot);
        out.push(decode_one(engine, t, &mut view));
    }
    out
}

/// Feed `seq[resume_from..]` through a PagedKv slot in prefill chunks of
/// `chunk` positions; returns the logits of the final position.
fn paged_prefill_chunked(
    kv: &mut PagedKv,
    engine: &mut Engine,
    slot: usize,
    seq: &[i32],
    resume_from: usize,
    chunk: usize,
) -> Vec<f32> {
    let mut last = Vec::new();
    let mut fed = resume_from;
    while fed < seq.len() {
        let take = chunk.min(seq.len() - fed);
        let mut need = vec![0usize; kv.num_slots()];
        need[slot] = take;
        assert!(kv.prepare_step_n(&need).is_empty(), "no preemption");
        kv.push_tokens(slot, &seq[fed..fed + take]);
        let plan = StepPlan {
            items: vec![StepItem::prefill(
                0,
                seq[fed..fed + take].to_vec(),
                LogitsMode::Last,
            )],
        };
        let mut seqs = kv.seqs(vec![slot]);
        last = engine
            .step(&plan, &mut seqs)
            .into_iter()
            .next()
            .unwrap()
            .data;
        fed += take;
    }
    last
}

#[test]
fn paged_f32_decode_bit_identical_to_contiguous() {
    let store = micro_store(71);
    let cfg = store.cfg;
    let w = Weights::Fp(&store);
    let seq: Vec<i32> = (0..20).map(|i| (i * 13 + 5) % 256).collect();

    // contiguous-cache reference
    let mut engine = Engine::new(&w);
    let mut cache = KvCache::new(cfg);
    let mut reference = Vec::new();
    for &t in &seq {
        reference.push(decode_one(&mut engine, t, &mut cache));
    }

    // paged F32, cold
    let layout = KvLayout::new(&cfg, 4);
    let mut kv = PagedKv::new(Box::new(F32Blocks::new(layout, 32)), 32, 2);
    assert_eq!(kv.admit(0, &seq, 1), Some(0));
    let paged = paged_decode(&mut kv, &mut engine, 0, &seq, 0);
    assert_eq!(reference, paged, "paged F32 logits must be bit-identical");

    // paged F32 resuming from shared prefix blocks: the final prompt
    // token re-decodes on top of cached KV and must still match bitwise
    let hit = kv.admit(1, &seq, 1).unwrap();
    assert!(hit > 0, "second admit should hit the cached prefix");
    let tail = paged_decode(&mut kv, &mut engine, 1, &seq, hit);
    assert_eq!(
        &reference[hit..],
        &tail[..],
        "prefix-shared decode diverged from the contiguous path"
    );
}

#[test]
fn chunked_prefill_bitwise_identical_dense_kv() {
    // the PR acceptance property: chunked prefill == per-token prefill,
    // bitwise, for dense KV (contiguous and paged F32), across chunk
    // sizes including 1, a non-divisor, a power of two, and larger than
    // the prompt — over ragged prompt lengths
    let store = micro_store(75);
    let cfg = store.cfg;
    let w = Weights::Fp(&store);
    let prompts: Vec<Vec<i32>> = [13usize, 7, 30]
        .iter()
        .enumerate()
        .map(|(i, &n)| {
            (0..n as i32).map(|j| (j * 29 + i as i32 * 3 + 1) % 256).collect()
        })
        .collect();

    for prompt in &prompts {
        // per-token reference on a contiguous cache
        let mut engine = Engine::new(&w);
        let mut c_ref = KvCache::new(cfg);
        let mut last_ref = Vec::new();
        for &t in prompt {
            last_ref = decode_one(&mut engine, t, &mut c_ref);
        }

        for chunk in [1usize, 7, 64, prompt.len() + 9] {
            // contiguous cache, chunked
            let mut cache = KvCache::new(cfg);
            let mut fed = 0usize;
            let mut last = Vec::new();
            while fed < prompt.len() {
                let take = chunk.min(prompt.len() - fed);
                let plan = StepPlan {
                    items: vec![StepItem::prefill(
                        0,
                        prompt[fed..fed + take].to_vec(),
                        LogitsMode::Last,
                    )],
                };
                let mut refs: Vec<&mut dyn KvSeq> = vec![&mut cache];
                last = engine
                    .step(&plan, &mut SeqRefs(&mut refs))
                    .into_iter()
                    .next()
                    .unwrap()
                    .data;
                fed += take;
            }
            assert_eq!(
                last, last_ref,
                "contiguous: chunk {} len {}",
                chunk,
                prompt.len()
            );

            // paged F32, chunked
            let layout = KvLayout::new(&cfg, 4);
            let mut kv =
                PagedKv::new(Box::new(F32Blocks::new(layout, 32)), 32, 1);
            kv.admit(0, prompt, 1).unwrap();
            let last_p = paged_prefill_chunked(
                &mut kv, &mut engine, 0, prompt, 0, chunk,
            );
            assert_eq!(
                last_p, last_ref,
                "paged: chunk {} len {}",
                chunk,
                prompt.len()
            );

            // decode continuation must agree too (cache state intact)
            let a = decode_one(&mut engine, 42, &mut cache);
            let mut c2 = c_ref.clone();
            let b = decode_one(&mut engine, 42, &mut c2);
            assert_eq!(a, b, "continuation after chunk {}", chunk);
        }
    }
}

#[test]
fn chunked_prefill_after_prefix_skip_bitwise() {
    // prefix-skip interaction: a second request sharing the prompt
    // resumes mid-prompt (admit returns the cached position) and feeds
    // the remainder as one chunk — still bitwise vs per-token
    let store = micro_store(76);
    let cfg = store.cfg;
    let w = Weights::Fp(&store);
    let seq: Vec<i32> = (0..17).map(|i| (i * 19 + 2) % 256).collect();
    let mut engine = Engine::new(&w);

    let layout = KvLayout::new(&cfg, 4);
    let mut kv = PagedKv::new(Box::new(F32Blocks::new(layout, 64)), 64, 3);
    kv.admit(0, &seq, 1).unwrap();
    let reference = paged_decode(&mut kv, &mut engine, 0, &seq, 0);

    // per-token resume
    let hit = kv.admit(1, &seq, 1).unwrap();
    assert!(hit > 0);
    let tail = paged_decode(&mut kv, &mut engine, 1, &seq, hit);
    assert_eq!(&reference[hit..], &tail[..]);

    // chunked resume (whole remainder in one chunk)
    let hit2 = kv.admit(2, &seq, 1).unwrap();
    assert_eq!(hit2, hit);
    let last = paged_prefill_chunked(
        &mut kv, &mut engine, 2, &seq, hit2, seq.len(),
    );
    assert_eq!(
        &last,
        reference.last().unwrap(),
        "chunked prefix-skip resume diverged"
    );
}

#[test]
fn chunked_prefill_lut_blocks_within_tolerance() {
    // LUT block stores seal (quantize) filled blocks, so chunked and
    // per-token prefill see slightly different staged/sealed mixes —
    // they must stay within the block store's golden tolerance
    let store = micro_store(77);
    let cfg = store.cfg;
    let w = Weights::Fp(&store);
    let seq: Vec<i32> = (0..20).map(|i| (i * 7 + 3) % 256).collect();
    let layout = KvLayout::new(&cfg, 4);
    let mut engine = Engine::new(&w);

    let mut kv_t = PagedKv::new(Box::new(LutBlocks::new(layout, 32)), 32, 1);
    kv_t.admit(0, &seq, 1).unwrap();
    let per_token = paged_decode(&mut kv_t, &mut engine, 0, &seq, 0);
    assert!(kv_t.stats().sealed_blocks > 0);

    for chunk in [1usize, 7, 64] {
        let mut kv_c =
            PagedKv::new(Box::new(LutBlocks::new(layout, 32)), 32, 1);
        kv_c.admit(0, &seq, 1).unwrap();
        let last = paged_prefill_chunked(
            &mut kv_c, &mut engine, 0, &seq, 0, chunk,
        );
        assert!(kv_c.stats().sealed_blocks > 0, "chunk {} sealed", chunk);
        let expect = per_token.last().unwrap();
        assert!(
            prop::all_close(&last, expect, 1e-3, 1e-3),
            "chunk {}: maxdiff {}",
            chunk,
            prop::max_abs_diff(&last, expect)
        );
    }
}

#[test]
fn paged_lut4_decode_tracks_f32_within_tolerance() {
    let store = micro_store(72);
    let cfg = store.cfg;
    let w = Weights::Fp(&store);
    let seq: Vec<i32> = (0..24).map(|i| (i * 7 + 3) % 256).collect();
    let mut engine = Engine::new(&w);

    let layout = KvLayout::new(&cfg, 4);
    let mut kv_f = PagedKv::new(Box::new(F32Blocks::new(layout, 32)), 32, 1);
    kv_f.admit(0, &seq, 1).unwrap();
    let exact = paged_decode(&mut kv_f, &mut engine, 0, &seq, 0);

    let mut kv_q = PagedKv::new(Box::new(LutBlocks::new(layout, 32)), 32, 1);
    kv_q.admit(0, &seq, 1).unwrap();
    let quant = paged_decode(&mut kv_q, &mut engine, 0, &seq, 0);
    assert!(kv_q.stats().sealed_blocks >= 5, "blocks must have sealed");

    // golden tolerance: 4-bit non-uniform KV blocks stay close to the
    // exact attention output in relative L2 over the whole sequence
    let mut num = 0.0f64;
    let mut den = 0.0f64;
    for (e, q) in exact.iter().zip(&quant) {
        for (&a, &b) in e.iter().zip(q) {
            num += ((a - b) as f64).powi(2);
            den += (a as f64).powi(2);
        }
    }
    let rel = (num / den.max(1e-12)).sqrt();
    assert!(rel < 0.30, "relative L2 {} too large", rel);
}

#[test]
fn paged_admits_1_5x_more_concurrent_requests_at_same_memory() {
    let store = micro_store(73);
    let cfg = store.cfg;
    // 50%-shared-prefix workload: 32-token prompts, first 16 shared
    let shared: Vec<i32> = (0..16).map(|i| 200 + i).collect();
    let reqs: Vec<GenRequest> = (0..12)
        .map(|i| {
            let mut prompt = shared.clone();
            prompt.extend((0..16).map(|j| (i * 16 + j) as i32 % 199));
            GenRequest::greedy(i as u64, prompt, 16)
        })
        .collect();

    // contiguous baseline: ctx-sized cache per slot
    let slot_bytes =
        cfg.layers * cfg.heads * cfg.ctx * cfg.head_dim() * 4 * 2;
    let budget = 4 * slot_bytes;
    let mut contiguous = NativeBackend::new(Weights::Fp(&store), 4);
    let (resp_c, m_c) = serve(&mut contiguous, reqs.clone()).unwrap();
    assert_eq!(m_c.peak_concurrency, 4);

    // paged backend at the same KV memory budget
    let mut paged = PagedNativeBackend::with_memory_budget(
        Weights::Fp(&store),
        16,
        16,
        KvStoreKind::F32,
        budget,
    );
    let (resp_p, m_p) = serve(&mut paged, reqs).unwrap();

    // identical greedy outputs, even across preemptions
    assert_eq!(resp_c.len(), resp_p.len());
    for (c, p) in resp_c.iter().zip(&resp_p) {
        assert_eq!(c.id, p.id);
        assert_eq!(c.tokens, p.tokens, "req {}", c.id);
    }

    // the acceptance criterion: >= 1.5x concurrent requests
    assert!(
        m_p.peak_concurrency * 2 >= m_c.peak_concurrency * 3,
        "paged {} vs contiguous {}: below 1.5x",
        m_p.peak_concurrency,
        m_c.peak_concurrency
    );
    let kv = m_p.kv.expect("pool stats");
    assert!(
        kv.peak_blocks_in_use <= kv.blocks_total,
        "pool overcommitted physically: {:?}",
        kv
    );
}

#[test]
fn audit_default_tracks_build_and_env() {
    // the gating contract: on by default under debug_assertions, else
    // only when GANQ_AUDIT=1 — this pins both halves depending on how
    // the suite was compiled/invoked
    let cfg = ModelConfig::builtin("opt-micro").unwrap();
    let layout = KvLayout::new(&cfg, 4);
    let kv = PagedKv::new(Box::new(F32Blocks::new(layout, 8)), 8, 1);
    let want = cfg!(debug_assertions)
        || std::env::var("GANQ_AUDIT").ok().as_deref() == Some("1");
    assert_eq!(kv.audit_enabled(), want);
}

#[test]
fn audited_serve_runs_sweeps_and_stays_clean() {
    let store = micro_store(78);
    let reqs: Vec<GenRequest> = (0..5)
        .map(|i| {
            GenRequest::greedy(i as u64 + 1, vec![3 + i, 9, 1 + i, 4], 8)
        })
        .collect();
    // a pool small enough to force preemption mid-run, so the audit
    // sweeps cover eviction and re-admission too
    let mut be = PagedNativeBackend::new(
        Weights::Fp(&store),
        3,
        4,
        14,
        KvStoreKind::F32,
    );
    be.kv_mut().set_audit(true);
    let (resp, m) = serve(&mut be, reqs).unwrap();
    assert_eq!(resp.len(), 5);
    assert!(m.preemptions > 0, "pool never filled: {:?}", m.kv);
    assert!(be.kv().audits_run() > 0, "audit hooks never fired");
    be.kv().audit().expect("post-serve audit clean");
}

#[test]
fn audit_disabled_runs_zero_sweeps() {
    // the zero-overhead pin: with audits off, maybe_audit() is a single
    // boolean test and the sweep counter stays at zero for a whole serve
    let store = micro_store(79);
    let reqs =
        vec![GenRequest::greedy(1, vec![5, 6, 7], 6)];
    let mut be = PagedNativeBackend::new(
        Weights::Fp(&store),
        2,
        4,
        32,
        KvStoreKind::F32,
    );
    be.kv_mut().set_audit(false);
    let (resp, _) = serve(&mut be, reqs).unwrap();
    assert_eq!(resp.len(), 1);
    assert_eq!(be.kv().audits_run(), 0, "disabled audit still swept");
}

#[test]
fn audit_catches_injected_refcount_leak() {
    let store = micro_store(80);
    let cfg = store.cfg;
    let seq: Vec<i32> = (0..9).map(|i| (i * 11 + 1) % 256).collect();
    let layout = KvLayout::new(&cfg, 4);
    let mut kv = PagedKv::new(Box::new(F32Blocks::new(layout, 16)), 16, 2);
    kv.admit(0, &seq, 1).unwrap();
    let mut need = vec![0usize; kv.num_slots()];
    need[0] = seq.len();
    assert!(kv.prepare_step_n(&need).is_empty());
    kv.push_tokens(0, &seq);
    assert!(kv.stats().blocks_in_use > 0);
    kv.audit().expect("clean before the leak is injected");

    // leak one reference: block 0 is either in use (conservation break)
    // or free (nonzero refcount on the free list) — the audit must
    // report the pool as corrupt either way
    kv.debug_retain_block(0);
    let err = kv.audit().expect_err("audit missed an injected leak");
    assert!(
        err.contains("refcount") || err.contains("free list"),
        "unexpected audit error: {}",
        err
    );
}
