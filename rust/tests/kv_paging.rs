//! Paged KV-cache integration tests: bit-exactness of the F32 block
//! store against the contiguous cache, tolerance of the LUT block store,
//! and the admission-capacity win of paging + prefix sharing at a fixed
//! KV memory budget (the PR's acceptance criterion).

use ganq::coordinator::{
    serve, KvStoreKind, NativeBackend, PagedNativeBackend, Request,
};
use ganq::kv::{F32Blocks, KvLayout, LutBlocks, PagedKv};
use ganq::model::forward::{self, KvCache, Weights};
use ganq::model::{ModelConfig, WeightStore};

fn micro_store(seed: u64) -> WeightStore {
    let cfg = ModelConfig::builtin("opt-micro").unwrap();
    WeightStore::random("t", cfg, seed)
}

/// Decode `seq` through a fresh PagedKv slot, returning per-step logits.
/// `resume_from` positions are assumed cached (prefix hit) and skipped.
fn paged_decode(
    kv: &mut PagedKv,
    w: &Weights,
    slot: usize,
    seq: &[i32],
    resume_from: usize,
) -> Vec<Vec<f32>> {
    let mut out = Vec::new();
    for &t in &seq[resume_from..] {
        let mut active = vec![false; kv.num_slots()];
        active[slot] = true;
        assert!(kv.prepare_step(&active).is_empty(), "no preemption");
        kv.push_token(slot, t);
        let mut view = kv.slot_view(slot);
        out.push(forward::decode_step_kv(w, t, &mut view));
    }
    out
}

#[test]
fn paged_f32_decode_bit_identical_to_contiguous() {
    let store = micro_store(71);
    let cfg = store.cfg;
    let w = Weights::Fp(&store);
    let seq: Vec<i32> = (0..20).map(|i| (i * 13 + 5) % 256).collect();

    // pre-refactor native path: contiguous KvCache
    let mut cache = KvCache::new(cfg);
    let mut reference = Vec::new();
    for &t in &seq {
        reference.push(forward::decode_step(&w, t, &mut cache));
    }

    // paged F32, cold
    let layout = KvLayout::new(&cfg, 4);
    let mut kv = PagedKv::new(Box::new(F32Blocks::new(layout, 32)), 32, 2);
    assert_eq!(kv.admit(0, &seq, 1), Some(0));
    let paged = paged_decode(&mut kv, &w, 0, &seq, 0);
    assert_eq!(reference, paged, "paged F32 logits must be bit-identical");

    // paged F32 resuming from shared prefix blocks: the final prompt
    // token re-decodes on top of cached KV and must still match bitwise
    let hit = kv.admit(1, &seq, 1).unwrap();
    assert!(hit > 0, "second admit should hit the cached prefix");
    let tail = paged_decode(&mut kv, &w, 1, &seq, hit);
    assert_eq!(
        &reference[hit..],
        &tail[..],
        "prefix-shared decode diverged from the contiguous path"
    );
}

#[test]
fn paged_lut4_decode_tracks_f32_within_tolerance() {
    let store = micro_store(72);
    let cfg = store.cfg;
    let w = Weights::Fp(&store);
    let seq: Vec<i32> = (0..24).map(|i| (i * 7 + 3) % 256).collect();

    let layout = KvLayout::new(&cfg, 4);
    let mut kv_f = PagedKv::new(Box::new(F32Blocks::new(layout, 32)), 32, 1);
    kv_f.admit(0, &seq, 1).unwrap();
    let exact = paged_decode(&mut kv_f, &w, 0, &seq, 0);

    let mut kv_q = PagedKv::new(Box::new(LutBlocks::new(layout, 32)), 32, 1);
    kv_q.admit(0, &seq, 1).unwrap();
    let quant = paged_decode(&mut kv_q, &w, 0, &seq, 0);
    assert!(kv_q.stats().sealed_blocks >= 5, "blocks must have sealed");

    // golden tolerance: 4-bit non-uniform KV blocks stay close to the
    // exact attention output in relative L2 over the whole sequence
    let mut num = 0.0f64;
    let mut den = 0.0f64;
    for (e, q) in exact.iter().zip(&quant) {
        for (&a, &b) in e.iter().zip(q) {
            num += ((a - b) as f64).powi(2);
            den += (a as f64).powi(2);
        }
    }
    let rel = (num / den.max(1e-12)).sqrt();
    assert!(rel < 0.30, "relative L2 {} too large", rel);
}

#[test]
fn paged_admits_1_5x_more_concurrent_requests_at_same_memory() {
    let store = micro_store(73);
    let cfg = store.cfg;
    // 50%-shared-prefix workload: 32-token prompts, first 16 shared
    let shared: Vec<i32> = (0..16).map(|i| 200 + i).collect();
    let reqs: Vec<Request> = (0..12)
        .map(|i| {
            let mut prompt = shared.clone();
            prompt.extend((0..16).map(|j| (i * 16 + j) as i32 % 199));
            Request { id: i as u64, prompt, max_new: 16 }
        })
        .collect();

    // contiguous baseline: ctx-sized cache per slot
    let slot_bytes =
        cfg.layers * cfg.heads * cfg.ctx * cfg.head_dim() * 4 * 2;
    let budget = 4 * slot_bytes;
    let mut contiguous = NativeBackend::new(Weights::Fp(&store), 4);
    let (resp_c, m_c) = serve(&mut contiguous, reqs.clone()).unwrap();
    assert_eq!(m_c.peak_concurrency, 4);

    // paged backend at the same KV memory budget
    let mut paged = PagedNativeBackend::with_memory_budget(
        Weights::Fp(&store),
        16,
        16,
        KvStoreKind::F32,
        budget,
    );
    let (resp_p, m_p) = serve(&mut paged, reqs).unwrap();

    // identical greedy outputs, even across preemptions
    assert_eq!(resp_c.len(), resp_p.len());
    for (c, p) in resp_c.iter().zip(&resp_p) {
        assert_eq!(c.id, p.id);
        assert_eq!(c.tokens, p.tokens, "req {}", c.id);
    }

    // the acceptance criterion: >= 1.5x concurrent requests
    assert!(
        m_p.peak_concurrency * 2 >= m_c.peak_concurrency * 3,
        "paged {} vs contiguous {}: below 1.5x",
        m_p.peak_concurrency,
        m_c.peak_concurrency
    );
    let kv = m_p.kv.expect("pool stats");
    assert!(
        kv.peak_blocks_in_use <= kv.blocks_total,
        "pool overcommitted physically: {:?}",
        kv
    );
}
