//! Golden-fixture tests: pin the Rust-native reimplementations to the
//! Python reference semantics via artifacts/golden/*.json (emitted by
//! aot.py). Skipped gracefully when artifacts have not been built.

use ganq::data::corpus::{self, Split};
use ganq::model::{ModelConfig, WeightStore};
use ganq::quant::{self, Quantizer};
use ganq::tensor::{linalg, Mat};
use ganq::util::json::Json;

fn golden(name: &str) -> Option<Json> {
    let path = ganq::util::artifacts_dir().join("golden").join(name);
    let txt = std::fs::read_to_string(&path).ok()?;
    Some(Json::parse(&txt).expect("golden parses"))
}

macro_rules! require {
    ($e:expr) => {
        match $e {
            Some(v) => v,
            None => {
                eprintln!("skipping: artifacts not built");
                return;
            }
        }
    };
}

#[test]
fn corpus_bytes_identical_to_python() {
    let g = require!(golden("corpus.json"));
    for flavor in ["wiki2s", "c4s", "ptbs"] {
        let f = corpus::flavor(flavor).unwrap();
        let ours = corpus::generate(f, Split::Train, 512);
        let theirs = g.get(flavor).unwrap().as_str().unwrap();
        assert_eq!(
            String::from_utf8(ours).unwrap(),
            theirs,
            "flavor {} diverged from python",
            flavor
        );
        let ours_v = corpus::generate(f, Split::Valid, 256);
        let theirs_v =
            g.get(&format!("{}_valid", flavor)).unwrap().as_str().unwrap();
        assert_eq!(String::from_utf8(ours_v).unwrap(), theirs_v);
    }
    let ours_i = corpus::instruct_text(256, corpus::INSTRUCT_SEED);
    assert_eq!(
        String::from_utf8(ours_i).unwrap(),
        g.get("instruct").unwrap().as_str().unwrap()
    );
}

#[test]
fn rtn_matches_python_reference() {
    let g = require!(golden("rtn.json"));
    let m = g.get("m").unwrap().as_usize().unwrap();
    let n = g.get("n").unwrap().as_usize().unwrap();
    let w = Mat::from_vec(m, n, g.get("w").unwrap().as_f32_vec().unwrap());
    let (codes, t) = ganq::quant::rtn::rtn_codebook(&w, 4);
    let q_ref = g.get("q").unwrap().as_f32_vec().unwrap();
    let t_ref = g.get("t").unwrap().as_f32_vec().unwrap();
    for (i, (&c, &cr)) in codes.iter().zip(q_ref.iter()).enumerate() {
        assert_eq!(c as f32, cr, "code {} differs", i);
    }
    for (i, (&a, &b)) in t.data.iter().zip(t_ref.iter()).enumerate() {
        assert!((a - b).abs() < 1e-5, "codebook {} differs: {} {}", i, a, b);
    }
}

#[test]
fn pack_layouts_match_python() {
    let g = require!(golden("pack.json"));
    // nibble
    let m = g.get("q4_m").unwrap().as_usize().unwrap();
    let n = g.get("q4_n").unwrap().as_usize().unwrap();
    let q: Vec<u8> = g
        .get("q4")
        .unwrap()
        .as_f32_vec()
        .unwrap()
        .iter()
        .map(|&v| v as u8)
        .collect();
    let lut = ganq::quant::lut::lut_from_parts(
        m,
        n,
        4,
        q,
        Mat::zeros(m, 16),
    );
    let packed: Vec<f32> =
        lut.packed_nibbles().iter().map(|&b| b as f32).collect();
    assert_eq!(packed, g.get("packed4").unwrap().as_f32_vec().unwrap());
    // dense 3-bit
    let m3 = g.get("q3_m").unwrap().as_usize().unwrap();
    let n3 = g.get("q3_n").unwrap().as_usize().unwrap();
    let q3: Vec<u8> = g
        .get("q3")
        .unwrap()
        .as_f32_vec()
        .unwrap()
        .iter()
        .map(|&v| v as u8)
        .collect();
    let lut3 = ganq::quant::lut::lut_from_parts(
        m3,
        n3,
        3,
        q3,
        Mat::zeros(m3, 8),
    );
    let packed3: Vec<f32> =
        lut3.packed3().iter().map(|&b| b as f32).collect();
    assert_eq!(packed3, g.get("packed3").unwrap().as_f32_vec().unwrap());
}

#[test]
fn outlier_split_matches_python() {
    let g = require!(golden("outlier.json"));
    let m = g.get("m").unwrap().as_usize().unwrap();
    let n = g.get("n").unwrap().as_usize().unwrap();
    let ratio = g.get("ratio").unwrap().as_f64().unwrap();
    let w = Mat::from_vec(m, n, g.get("w").unwrap().as_f32_vec().unwrap());
    let (sp, dn) = ganq::quant::outlier::split_outliers(&w, ratio);
    let sp_ref = g.get("sparse").unwrap().as_f32_vec().unwrap();
    let dn_ref = g.get("dense").unwrap().as_f32_vec().unwrap();
    for i in 0..m * n {
        assert!((sp.data[i] - sp_ref[i]).abs() < 1e-6, "sparse[{}]", i);
        assert!((dn.data[i] - dn_ref[i]).abs() < 1e-6, "dense[{}]", i);
    }
}

#[test]
fn ganq_native_matches_python_reference() {
    let g = require!(golden("ganq.json"));
    let m = g.get("m").unwrap().as_usize().unwrap();
    let n = g.get("n").unwrap().as_usize().unwrap();
    let bits = g.get("bits").unwrap().as_usize().unwrap() as u8;
    let iters = g.get("iters").unwrap().as_usize().unwrap();
    let w = Mat::from_vec(m, n, g.get("w").unwrap().as_f32_vec().unwrap());
    let h = Mat::from_vec(n, n, g.get("h").unwrap().as_f32_vec().unwrap());
    let final_err_ref = g.get("final_err").unwrap().as_f64().unwrap();
    let rtn_err_ref = g.get("rtn_err").unwrap().as_f64().unwrap();

    let q = ganq::quant::ganq::Ganq::with_iters(bits, iters);
    let r = q.quantize(&w, &h);
    let hp = linalg::precondition(&h);
    let err = linalg::layer_error(&w, &r.w_hat, &hp);
    // both solvers are alternating heuristics in different float widths;
    // they must agree on the quality level (within a few percent) and both
    // must clearly beat RTN
    assert!(
        (err - final_err_ref).abs() < 0.10 * final_err_ref.max(1e-9),
        "rust {} vs python {}",
        err,
        final_err_ref
    );
    assert!(err < rtn_err_ref, "rust ganq {} !< rtn {}", err, rtn_err_ref);

    // python per-iteration errors were monotone; verify the fixture
    let errs = g.get("errs").unwrap().as_f64_vec().unwrap();
    for win in errs.windows(2) {
        assert!(win[1] <= win[0] * 1.0001 + 1e-9);
    }
}

#[test]
fn native_forward_matches_python_on_trained_weights() {
    let g = require!(golden("fwd.json"));
    let model = g.get("model").unwrap().as_str().unwrap().to_string();
    let cfg = ModelConfig::builtin(&model).unwrap();
    let base = ganq::util::artifacts_dir();
    let store = match WeightStore::load(&base, &model, cfg) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("skipping: weights not built ({})", e);
            return;
        }
    };
    let tokens: Vec<i32> = g
        .get("tokens")
        .unwrap()
        .as_f32_vec()
        .unwrap()
        .iter()
        .map(|&v| v as i32)
        .collect();
    let logits_ref = g.get("logits_last").unwrap().as_f32_vec().unwrap();
    let nll_ref = g.get("nll_sum").unwrap().as_f64().unwrap();

    let w = ganq::model::forward::Weights::Fp(&store);
    let logits =
        ganq::model::forward::forward_full(&w, &[tokens.clone()], None);
    let last = logits.row(tokens.len() - 1);
    let maxdiff: f32 = last
        .iter()
        .zip(&logits_ref)
        .map(|(&a, &b)| (a - b).abs())
        .fold(0.0, f32::max);
    assert!(maxdiff < 2e-2, "logits diverge from jax: maxdiff {}", maxdiff);

    let nll = ganq::model::forward::nll_sum(&w, &[tokens]);
    assert!(
        (nll - nll_ref).abs() < 0.01 * nll_ref.abs().max(1.0),
        "nll {} vs {}",
        nll,
        nll_ref
    );
}

#[test]
fn paged_f32_decode_bit_identical_on_golden_fixture() {
    // acceptance: the paged F32 block store reproduces the pre-refactor
    // native decode path bit-for-bit on the trained-weights fixture
    let g = require!(golden("fwd.json"));
    let model = g.get("model").unwrap().as_str().unwrap().to_string();
    let cfg = ModelConfig::builtin(&model).unwrap();
    let base = ganq::util::artifacts_dir();
    let store = match WeightStore::load(&base, &model, cfg) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("skipping: weights not built ({})", e);
            return;
        }
    };
    let tokens: Vec<i32> = g
        .get("tokens")
        .unwrap()
        .as_f32_vec()
        .unwrap()
        .iter()
        .map(|&v| v as i32)
        .collect();

    use ganq::model::forward::{Engine, KvSeq, SeqRefs};
    let w = ganq::model::forward::Weights::Fp(&store);
    let mut engine = Engine::new(&w);
    let mut cache = ganq::model::forward::KvCache::new(cfg);
    let mut native_last = Vec::new();
    for &t in &tokens {
        let mut refs: Vec<&mut dyn KvSeq> = vec![&mut cache];
        native_last = engine
            .decode_batch(&[t], &mut SeqRefs(&mut refs))
            .into_iter()
            .next()
            .unwrap();
    }

    let layout = ganq::kv::KvLayout::new(&cfg, 8);
    let blocks = tokens.len().div_ceil(8) + 2;
    let mut kv = ganq::kv::PagedKv::new(
        Box::new(ganq::kv::F32Blocks::new(layout, blocks)),
        blocks,
        1,
    );
    kv.admit(0, &tokens, 1).unwrap();
    let mut paged_last = Vec::new();
    for &t in &tokens {
        assert!(kv.prepare_step(&[true]).is_empty());
        kv.push_token(0, t);
        let mut seqs = kv.seqs(vec![0]);
        paged_last = engine
            .decode_batch(&[t], &mut seqs)
            .into_iter()
            .next()
            .unwrap();
    }
    assert_eq!(
        native_last, paged_last,
        "paged decode diverged from the native path on the fixture"
    );
}

#[test]
fn quant_methods_ordering_on_trained_layer() {
    // the paper's per-layer story on REAL trained weights: ganq < gptq,
    // ganq < omniq, ganq < rtn (layer error, 3-bit)
    let base = ganq::util::artifacts_dir();
    let cfg = match ModelConfig::builtin("opt-micro") {
        Some(c) => c,
        None => return,
    };
    let store = match WeightStore::load(&base, "opt-micro", cfg) {
        Ok(s) => s,
        Err(_) => {
            eprintln!("skipping: weights not built");
            return;
        }
    };
    let calib = ganq::coordinator::calibrate(&store, 8, 64);
    let w = store.mat("l0.wq");
    let h = &calib.grams["l0.wq"];
    let mut errs = std::collections::BTreeMap::new();
    for name in ["rtn", "gptq", "omniq", "ganq"] {
        let q = quant::by_name(name, 3).unwrap();
        errs.insert(name, q.quantize(&w, h).layer_error(&w, h));
    }
    assert!(errs["ganq"] < errs["rtn"], "{:?}", errs);
    assert!(errs["ganq"] < errs["omniq"], "{:?}", errs);
    assert!(errs["ganq"] < errs["gptq"] * 1.02, "{:?}", errs);
}
