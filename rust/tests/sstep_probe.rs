//! Second root-cause probe (see while_loop_probe.rs): a 4-column miniature
//! of the GANQ S-step scan, with known expected outputs computed by jax.
//! Exposes whether dynamic-slice-by-scanned-index / reverse / layout
//! behaviour diverges on xla_extension 0.5.1.

#[test]
fn sstep_miniature_roundtrip() {
    let path = "/tmp/sstep_probe.hlo.txt";
    if !std::path::Path::new(path).exists() {
        eprintln!("skipping: probe HLO not generated");
        return;
    }
    let client = xla::PjRtClient::cpu().unwrap();
    let proto = xla::HloModuleProto::from_text_file(path).unwrap();
    let comp = xla::XlaComputation::from_proto(&proto);
    let exe = client.compile(&comp).unwrap();
    let w: Vec<f32> = (0..8).map(|i| i as f32 * 0.3).collect();
    let mut l = vec![0f32; 16];
    for i in 0..4 {
        for j in 0..=i {
            l[i * 4 + j] = 1.0;
        }
        l[i * 4 + i] = 2.0;
    }
    let wl = xla::Literal::vec1(&w).reshape(&[2, 4]).unwrap();
    let ll = xla::Literal::vec1(&l).reshape(&[4, 4]).unwrap();
    let out = exe.execute::<xla::Literal>(&[wl, ll]).unwrap()[0][0]
        .to_literal_sync()
        .unwrap();
    let parts = out.to_tuple().unwrap();
    let q = parts[0].to_vec::<i32>().unwrap();
    let acc = parts[1].to_vec::<f32>().unwrap();
    eprintln!("q   = {:?}", q);
    eprintln!("acc = {:?}", acc);
    let expect_q = vec![0, 0, 1, 1, 1, 1, 2, 2];
    let expect_acc = vec![
        -0.19999993f32,
        0.10000008,
        -0.8999999,
        -0.19999993,
        0.8000003,
        0.9000002,
        -0.2999997,
        0.20000029,
    ];
    // NOTE: q's entry layout in the HLO text is {0,1} (column-major);
    // whether the raw read needs delinearization is exactly what this
    // probe decides.
    let q_transposed: Vec<i32> =
        (0..8).map(|p| q[(p % 2) * 4 + p / 2]).collect();
    eprintln!("q^T = {:?}", q_transposed);
    assert!(
        q == expect_q || q_transposed == expect_q,
        "q diverged beyond layout: {:?} (expected {:?})",
        q,
        expect_q
    );
    for (a, b) in acc.iter().zip(&expect_acc) {
        assert!((a - b).abs() < 1e-4, "acc diverged: {:?}", acc);
    }
}
