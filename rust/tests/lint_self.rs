//! The linter lints itself: the live tree must be clean, and every
//! seeded-violation fixture under `tests/fixtures/lint/` must fire
//! exactly the rules it was written to demonstrate. `cargo xtask lint`
//! runs the same engine over the same tree, so these tests keep the
//! lint honest without needing a second binary in the tier-1 loop.

use std::path::Path;

use ganq::lint::{build_ctx, lint_source, lint_tree};

fn crate_root() -> &'static Path {
    Path::new(env!("CARGO_MANIFEST_DIR"))
}

#[test]
fn live_tree_is_lint_clean() {
    let v = lint_tree(crate_root()).expect("lint tree walk");
    for x in &v {
        eprintln!("{}", x);
    }
    assert!(
        v.is_empty(),
        "{} lint violation(s) in the live tree (listed above)",
        v.len()
    );
}

/// Fixture file name -> rules it must (only) fire. An empty list means
/// the fixture must lint clean.
const EXPECT: &[(&str, &[&str])] = &[
    ("clean_allows.rs", &[]),
    ("hot_expect.rs", &["hot-expect"]),
    ("hot_index.rs", &["hot-index"]),
    ("hot_panic.rs", &["hot-panic"]),
    ("lock_inversion.rs", &["lock-rank"]),
    ("missing_safety.rs", &["safety-comment"]),
    ("naked_unwrap.rs", &["hot-unwrap"]),
    ("raw_mutex.rs", &["raw-mutex"]),
    ("unknown_rank.rs", &["lock-rank"]),
    ("unpaired_bench.rs", &["bench-gate"]),
    ("unregistered_trace.rs", &["trace-registry"]),
];

#[test]
fn fixtures_fire_their_seeded_rules() {
    let ctx = build_ctx(crate_root()).expect("lint context");
    let dir = crate_root().join("tests/fixtures/lint");
    for (file, rules) in EXPECT {
        let path = dir.join(file);
        let src = std::fs::read_to_string(&path)
            .unwrap_or_else(|e| panic!("read {}: {}", path.display(), e));
        let rel = src
            .lines()
            .next()
            .and_then(|l| l.strip_prefix("//@path: "))
            .map(str::trim)
            .unwrap_or_else(|| panic!("{} missing //@path header", file));
        let v = lint_source(rel, &src, &ctx);
        if rules.is_empty() {
            assert!(v.is_empty(), "{}: expected clean, got {:?}", file, v);
            continue;
        }
        for rule in *rules {
            assert!(
                v.iter().any(|x| x.rule == *rule),
                "{}: expected rule {} to fire, got {:?}",
                file,
                rule,
                v
            );
        }
        for x in &v {
            assert!(
                rules.contains(&x.rule),
                "{}: unexpected extra rule {}: {:?}",
                file,
                x.rule,
                v
            );
        }
    }
}

/// Every fixture on disk is accounted for in [`EXPECT`], so adding a
/// fixture without wiring its expectation fails loudly.
#[test]
fn fixture_corpus_matches_expectations() {
    let dir = crate_root().join("tests/fixtures/lint");
    let mut on_disk: Vec<String> = std::fs::read_dir(&dir)
        .expect("fixtures dir")
        .flatten()
        .map(|e| e.file_name().to_string_lossy().into_owned())
        .filter(|n| n.ends_with(".rs"))
        .collect();
    on_disk.sort();
    let mut listed: Vec<String> =
        EXPECT.iter().map(|(f, _)| f.to_string()).collect();
    listed.sort();
    assert_eq!(on_disk, listed);
}
