//! Observability integration tests: the step-level trace stays
//! well-formed (balanced, properly nested Begin/End spans; monotone
//! timestamps; valid Chrome `trace_event` JSON) through the messy serve
//! paths — preemption under KV pressure and mid-serve cancellation —
//! and the metrics snapshot carries the full latency decomposition.
//!
//! Each `#[test]` runs on its own thread, so the thread-local ring
//! recorder is naturally isolated between tests.

use ganq::coordinator::{
    serve, serve_events, FinishReason, GenRequest, KvStoreKind,
    PagedNativeBackend, ServeOptions, TokenEvent,
};
use ganq::model::forward::Weights;
use ganq::model::{ModelConfig, WeightStore};
use ganq::obs::trace::{self, Phase};
use ganq::util::json::Json;

fn micro_store(seed: u64) -> WeightStore {
    let cfg = ModelConfig::builtin("opt-micro").unwrap();
    WeightStore::random("t", cfg, seed)
}

/// 4 greedy requests whose KV demand (15 positions = 4 blocks each at
/// block size 4) cannot fit a 5-block pool concurrently, while any
/// single request can — so the run must preempt yet still finishes.
fn pressure_requests() -> Vec<GenRequest> {
    (0..4)
        .map(|i| GenRequest::greedy(i, vec![10 + i as i32, 20, 30], 12))
        .collect()
}

#[test]
fn trace_spans_balance_under_preemption_and_cancellation() {
    trace::enable(1 << 20);
    let store = micro_store(33);
    let reqs = pressure_requests();
    let cancel = reqs[3].cancel_handle();
    let mut be = PagedNativeBackend::new(
        Weights::Fp(&store),
        4,
        4,
        5,
        KvStoreKind::F32,
    );
    // cancel request 3 from inside the sink after its 2nd streamed token
    // — same thread as the scheduler, so the cancel deterministically
    // lands mid-serve and is honored at the next step boundary
    let mut streamed3 = 0usize;
    let (resp, m) = serve_events(
        &mut be,
        reqs,
        ServeOptions::default(),
        &mut |ev| {
            if let TokenEvent::Token { id, .. } = &ev {
                if *id == 3 {
                    streamed3 += 1;
                    if streamed3 == 2 {
                        cancel.cancel();
                    }
                }
            }
        },
    )
    .unwrap();
    let (events, dropped) = trace::take();
    trace::disable();

    // the run exercised both hard paths
    assert!(m.preemptions > 0, "pool of 5 blocks must force preemption");
    let r3 = resp.iter().find(|r| r.id == 3).unwrap();
    assert_eq!(r3.finish, FinishReason::Cancelled);
    assert!(m.finish.cancelled >= 1);

    // ring never overflowed, timestamps are monotone, spans nest
    assert_eq!(dropped, 0, "1M-event ring must not drop");
    assert!(!events.is_empty());
    let mut last_ts = f64::NEG_INFINITY;
    let mut stack: Vec<&'static str> = Vec::new();
    for ev in &events {
        assert!(ev.ts_us >= last_ts, "timestamps monotone");
        last_ts = ev.ts_us;
        match ev.ph {
            Phase::Begin => stack.push(ev.name),
            Phase::End => {
                let open = stack.pop().unwrap_or_else(|| {
                    panic!("End({}) without a Begin", ev.name)
                });
                assert_eq!(open, ev.name, "spans close in LIFO order");
            }
            Phase::Instant | Phase::Counter => {}
        }
    }
    assert!(stack.is_empty(), "unclosed spans: {:?}", stack);

    // the expected phases appear: scheduler, backend, engine, kv events
    let has = |name: &str, ph: Phase| {
        events.iter().any(|e| e.name == name && e.ph == ph)
    };
    assert!(has("sched.plan", Phase::Begin));
    assert!(has("backend.step", Phase::Begin));
    assert!(has("sched.sample", Phase::Begin));
    assert!(has("engine.step", Phase::Begin));
    assert!(has("engine.attn", Phase::Begin));
    assert!(has("sched.admit", Phase::Instant));
    assert!(has("sched.preempt", Phase::Instant));
    assert!(has("kv.preempt", Phase::Instant));
    assert!(has("sched.active", Phase::Counter));
    assert!(has("kv.occupancy", Phase::Counter));

    // the Chrome export of the same events parses and is well-formed
    let chrome = trace::export_chrome(&events, dropped);
    let parsed =
        Json::parse(&chrome.to_string_pretty()).expect("chrome JSON parses");
    let evs = parsed
        .get("traceEvents")
        .and_then(|t| t.as_arr())
        .expect("traceEvents array");
    assert_eq!(evs.len(), events.len());
    let mut jstack: Vec<String> = Vec::new();
    for e in evs {
        let name = e.get("name").and_then(|n| n.as_str()).expect("name");
        let ph = e.get("ph").and_then(|p| p.as_str()).expect("ph");
        assert!(e.get("ts").and_then(|t| t.as_f64()).is_some());
        assert!(e.get("pid").is_some() && e.get("tid").is_some());
        match ph {
            "B" => jstack.push(name.to_string()),
            "E" => assert_eq!(jstack.pop().as_deref(), Some(name)),
            "i" => {
                assert_eq!(
                    e.get("s").and_then(|s| s.as_str()),
                    Some("t"),
                    "instants carry thread scope"
                );
            }
            "C" => assert!(e.get("args").is_some()),
            other => panic!("unexpected phase {:?}", other),
        }
    }
    assert!(jstack.is_empty());
    assert_eq!(
        parsed.at(&["otherData", "dropped"]).and_then(|d| d.as_f64()),
        Some(0.0)
    );
}

#[test]
fn disabled_tracing_records_nothing_across_serve() {
    let store = micro_store(34);
    let mut be = PagedNativeBackend::new(
        Weights::Fp(&store),
        4,
        4,
        5,
        KvStoreKind::F32,
    );
    let (resp, _) = serve(&mut be, pressure_requests()).unwrap();
    assert_eq!(resp.len(), 4);
    let (events, dropped) = trace::take();
    assert!(events.is_empty(), "no recorder installed, nothing recorded");
    assert_eq!(dropped, 0);
}

#[test]
fn metrics_snapshot_carries_step_and_occupancy_histograms() {
    let store = micro_store(35);
    let mut be = PagedNativeBackend::new(
        Weights::Fp(&store),
        4,
        4,
        5,
        KvStoreKind::F32,
    );
    let (resp, m) = serve(&mut be, pressure_requests()).unwrap();
    assert_eq!(resp.len(), 4);

    // one step-latency sample per backend step, occupancy sampled each
    // step the pool reported stats
    assert_eq!(m.step_ms.count() as usize, m.decode_steps);
    assert!(m.kv_occupancy.count() > 0);
    assert!(m.kv_occupancy.max() <= 1.0 + 1e-9);

    // every completed request decomposes: ttft = queue delay + prefill
    for r in &m.requests {
        let (Some(ttft), Some(queue), Some(prefill)) =
            (r.ttft_ms(), r.queue_delay_ms(), r.prefill_ms())
        else {
            panic!("request {} missing timeline stamps", r.id);
        };
        assert!(
            (ttft - (queue + prefill)).abs() < 1e-6,
            "req {}: ttft {} != queue {} + prefill {}",
            r.id,
            ttft,
            queue,
            prefill
        );
        assert!(r.e2e_ms().unwrap() >= ttft);
    }

    // the snapshot is machine-readable and has the observability keys
    let snap = Json::parse(&m.snapshot().to_string_pretty())
        .expect("snapshot parses");
    for key in [
        "ttft_p50_ms",
        "ttft_p99_ms",
        "tpot_p50_ms",
        "tpot_p99_ms",
        "queue_delay_p50_ms",
        "queue_delay_p99_ms",
        "step_ms",
        "kv_occupancy",
        "kv_pool",
        "preemptions",
        "finish",
        "requests",
    ] {
        assert!(snap.get(key).is_some(), "snapshot missing {}", key);
    }
    assert_eq!(
        snap.get("requests").and_then(|r| r.as_arr()).unwrap().len(),
        4
    );
    assert_eq!(
        snap.at(&["step_ms", "count"]).and_then(|c| c.as_f64()),
        Some(m.decode_steps as f64)
    );
}

/// The speculative decode path narrates itself: every round leaves
/// `spec.draft` / `spec.verify` / `spec.accept` instants (plus
/// `spec.rollback` and `kv.truncate` when drafts miss), the adaptive
/// controller emits the `spec.k` counter, and the spans still balance.
#[test]
fn speculative_serve_emits_spec_trace_events() {
    use ganq::coordinator::{SpecBackend, SpecOptions};
    use ganq::model::{LayerWeights, QuantizedModel};
    use ganq::quant::lut::lut_from_parts;
    use ganq::quant::BitPlaneStore;
    use ganq::tensor::Mat;

    // nested any-precision model over random codes (the serve-test idiom)
    let store = micro_store(36);
    let mut rng = ganq::util::rng::Rng::new(0x5bec);
    let mut linears = std::collections::BTreeMap::new();
    for (name, mm, n) in store.cfg.linear_shapes() {
        let codes: Vec<u8> =
            (0..mm * n).map(|_| rng.below(16) as u8).collect();
        let cb = Mat::from_vec(
            mm,
            16,
            rng.normal_vec_f32(mm * 16)
                .into_iter()
                .map(|v| v * 0.08)
                .collect(),
        );
        let parent = lut_from_parts(mm, n, 4, codes, cb);
        linears.insert(
            name,
            LayerWeights::AnyPrec(BitPlaneStore::nest(&parent, &[2, 3, 4])),
        );
    }
    let qm = QuantizedModel {
        base: store,
        method: "ganq-anyprec".into(),
        bits: 4,
        linears,
        weight_bits: 0,
    };

    trace::enable(1 << 20);
    let mut be = SpecBackend::paged(
        &qm,
        2,
        4,
        64,
        KvStoreKind::F32,
        SpecOptions::new(2, 4),
    )
    .expect("backend");
    let reqs: Vec<GenRequest> = (0..2)
        .map(|i| GenRequest::greedy(i, vec![10 + i as i32, 20, 30], 10))
        .collect();
    let (resp, m) = serve(&mut be, reqs).unwrap();
    let (events, dropped) = trace::take();
    trace::disable();

    assert_eq!(resp.len(), 2);
    assert_eq!(dropped, 0);
    assert!(m.spec_rounds > 0, "greedy requests must speculate");
    let has = |name: &str, ph: Phase| {
        events.iter().any(|e| e.name == name && e.ph == ph)
    };
    assert!(has("spec.draft", Phase::Instant));
    assert!(has("spec.verify", Phase::Instant));
    assert!(has("spec.accept", Phase::Instant));
    if m.rollback_tokens > 0 {
        assert!(has("spec.rollback", Phase::Instant));
        assert!(has("kv.truncate", Phase::Instant));
    }
    // random 2-bit drafts miss often, so the adaptive controller must
    // have shrunk k at least once
    assert!(has("spec.k", Phase::Counter));
    // spans from the engines underneath still balance
    let mut depth = 0i64;
    for ev in &events {
        match ev.ph {
            Phase::Begin => depth += 1,
            Phase::End => depth -= 1,
            _ => {}
        }
        assert!(depth >= 0, "End without Begin at {}", ev.name);
    }
    assert_eq!(depth, 0, "unclosed spans");
}
