//! Self-speculative decoding integration tests: the exact-match
//! property — speculative greedy output is bitwise-identical to plain
//! greedy output — across draft widths, draft lengths, batch sizes,
//! and both KV layouts (dense caches and paged F32 blocks); plus the
//! rollback-then-preempt-then-resume path on a tiny block pool and
//! stop-criteria handling on speculatively committed tokens.

use ganq::coordinator::{
    serve, GenRequest, KvStoreKind, NativeBackend, SamplingParams,
    SpecBackend, SpecOptions, StopCriteria,
};
use ganq::model::forward::Weights;
use ganq::model::{
    LayerWeights, ModelConfig, QuantizedModel, WeightStore,
};
use ganq::quant::lut::lut_from_parts;
use ganq::quant::BitPlaneStore;
use ganq::tensor::Mat;

/// Quantized model whose every linear is a random nested any-precision
/// store (widths 2/3/4) — the serve-test idiom.
fn anyprec_model(seed: u64) -> QuantizedModel {
    let cfg = ModelConfig::builtin("opt-micro").unwrap();
    let store = WeightStore::random("t", cfg, seed);
    let mut rng = ganq::util::rng::Rng::new(seed ^ 0x5bec);
    let mut linears = std::collections::BTreeMap::new();
    for (name, m, n) in store.cfg.linear_shapes() {
        let codes: Vec<u8> = (0..m * n).map(|_| rng.below(16) as u8).collect();
        let cb = Mat::from_vec(
            m,
            16,
            rng.normal_vec_f32(m * 16)
                .into_iter()
                .map(|v| v * 0.08)
                .collect(),
        );
        let parent = lut_from_parts(m, n, 4, codes, cb);
        linears.insert(
            name,
            LayerWeights::AnyPrec(BitPlaneStore::nest(&parent, &[2, 3, 4])),
        );
    }
    QuantizedModel {
        base: store,
        method: "ganq-anyprec".into(),
        bits: 4,
        linears,
        weight_bits: 0,
    }
}

fn greedy_reqs(max_new: usize) -> Vec<GenRequest> {
    vec![
        GenRequest::greedy(1, vec![3, 4, 5, 6], max_new),
        GenRequest::greedy(2, vec![9, 1], max_new),
        GenRequest::greedy(3, vec![7, 7, 2, 8, 11], max_new),
        GenRequest::greedy(4, vec![12], max_new),
    ]
}

/// The tentpole property: speculative greedy decode is bitwise equal to
/// plain greedy decode — acceptance is temperature-0 exact-match, so a
/// mismatched draft is rolled back and replaced by the verifier's own
/// argmax. Sweeps draft width x draft length x batch x KV layout.
#[test]
fn speculative_greedy_matches_plain_greedy_everywhere() {
    let qm = anyprec_model(61);
    for batch in [1usize, 4] {
        let mut plain = NativeBackend::new(Weights::Quant(&qm), batch);
        let (want, _) = serve(&mut plain, greedy_reqs(10)).unwrap();
        for width in [2u8, 3] {
            for k in [1usize, 4, 8] {
                let so = SpecOptions::fixed(width, k);
                let mut dense =
                    SpecBackend::dense(&qm, batch, so).expect("backend");
                let (got, m) = serve(&mut dense, greedy_reqs(10)).unwrap();
                for (w, g) in want.iter().zip(&got) {
                    assert_eq!(
                        w.tokens, g.tokens,
                        "dense w={} k={} batch={} req {}",
                        width, k, batch, w.id
                    );
                    assert_eq!(w.finish, g.finish);
                }
                assert!(
                    m.spec_rounds > 0,
                    "dense w={} k={} batch={}: no speculation",
                    width,
                    k,
                    batch
                );
                assert_eq!(
                    m.accepted_tokens + m.rollback_tokens,
                    m.draft_tokens
                );

                let mut paged = SpecBackend::paged(
                    &qm,
                    batch,
                    8,
                    64,
                    KvStoreKind::F32,
                    so,
                )
                .expect("backend");
                let (got, m) = serve(&mut paged, greedy_reqs(10)).unwrap();
                for (w, g) in want.iter().zip(&got) {
                    assert_eq!(
                        w.tokens, g.tokens,
                        "paged w={} k={} batch={} req {}",
                        width, k, batch, w.id
                    );
                    assert_eq!(w.finish, g.finish);
                }
                assert!(m.spec_rounds > 0);
            }
        }
    }
}

/// Tiny block pool: speculation rounds roll drafts back while the pool
/// pressure forces preempt-and-resume — the combination must still be
/// token-identical to plain greedy decode (rollback-then-preempt-then-
/// resume is the hardest KV path in the subsystem).
#[test]
fn rollback_then_preempt_then_resume_is_token_identical() {
    let qm = anyprec_model(62);
    let reqs: Vec<GenRequest> = (0..4)
        .map(|i| {
            GenRequest::greedy(
                i as u64 + 1,
                vec![2 + i, 5, 9 - i, 4, 1 + i, 8],
                12,
            )
        })
        .collect();
    let mut plain = NativeBackend::new(Weights::Quant(&qm), 4);
    let (want, _) = serve(&mut plain, reqs.clone()).unwrap();

    // 12 blocks of 4 tokens cannot hold 4 sequences of 6+12 tokens:
    // the scheduler must preempt and resume mid-run
    let mut spec = SpecBackend::paged(
        &qm,
        4,
        4,
        12,
        KvStoreKind::F32,
        SpecOptions::fixed(2, 4),
    )
    .expect("backend");
    let (got, m) = serve(&mut spec, reqs).unwrap();
    for (w, g) in want.iter().zip(&got) {
        assert_eq!(w.tokens, g.tokens, "req {}", w.id);
        assert_eq!(w.finish, g.finish);
    }
    assert!(m.preemptions > 0, "pool never filled: {:?}", m.kv);
    assert!(m.spec_rounds > 0, "headroom never allowed a draft");
    assert!(
        m.rollback_tokens > 0,
        "random weights should reject some drafts"
    );
}

/// Mixed batch: greedy requests speculate, sampled requests fall back
/// to plain decode — and both must match the plain backend exactly
/// (sampling is a pure function of (seed, draw index)).
#[test]
fn mixed_greedy_and_sampled_batch_matches_plain() {
    let qm = anyprec_model(63);
    let sampled = SamplingParams {
        temperature: 0.9,
        top_k: 0,
        top_p: 1.0,
        seed: 17,
    };
    let reqs = vec![
        GenRequest::greedy(1, vec![3, 4, 5], 8),
        GenRequest::new(2, vec![9, 1], sampled, StopCriteria::max_tokens(8)),
        GenRequest::greedy(3, vec![7, 2, 8], 8),
        GenRequest::new(4, vec![6], sampled, StopCriteria::max_tokens(8)),
    ];
    let mut plain = NativeBackend::new(Weights::Quant(&qm), 4);
    let (want, _) = serve(&mut plain, reqs.clone()).unwrap();
    let mut spec =
        SpecBackend::dense(&qm, 4, SpecOptions::new(2, 4)).expect("backend");
    let (got, m) = serve(&mut spec, reqs).unwrap();
    for (w, g) in want.iter().zip(&got) {
        assert_eq!(w.tokens, g.tokens, "req {}", w.id);
        assert_eq!(w.finish, g.finish);
    }
    assert!(m.spec_rounds > 0, "greedy slots must still speculate");
}

/// Stop criteria fold over speculatively committed tokens in sampler
/// order: a stop token inside an accepted draft run ends the request at
/// the same position and with the same finish reason as plain decode.
#[test]
fn stop_token_inside_committed_run_matches_plain() {
    let qm = anyprec_model(64);
    // find what plain greedy emits, then make its third token a stop
    let mut plain = NativeBackend::new(Weights::Quant(&qm), 1);
    let (base, _) =
        serve(&mut plain, vec![GenRequest::greedy(1, vec![5, 6], 8)])
            .unwrap();
    assert!(base[0].tokens.len() >= 3, "need a stream to stop inside");
    let stop_tok = base[0].tokens[2];
    let stop =
        StopCriteria::max_tokens(8).with_stop_tokens(vec![stop_tok]);
    let req = GenRequest::new(
        1,
        vec![5, 6],
        SamplingParams::greedy(),
        stop,
    );

    let mut plain = NativeBackend::new(Weights::Quant(&qm), 1);
    let (want, _) = serve(&mut plain, vec![req.clone()]).unwrap();
    // a draft length past the stop position: the stop token lands
    // inside one committed run
    let mut spec =
        SpecBackend::dense(&qm, 1, SpecOptions::fixed(2, 8)).expect("backend");
    let (got, _) = serve(&mut spec, vec![req]).unwrap();
    assert_eq!(want[0].tokens, got[0].tokens);
    assert_eq!(want[0].finish, got[0].finish);
    assert_eq!(
        want[0].finish,
        ganq::coordinator::FinishReason::StopToken,
        "the stop token must end the request"
    );
    assert!(got[0].tokens.len() <= 2, "stopped at the stop token");
}

/// The paged-KV auditor runs inside speculative decode — mid-round
/// while the draft window is open (exercising the draft-isolation
/// invariant) and again at every step boundary. An audit-enabled run
/// must stay clean and remain token-identical to plain greedy decode.
#[test]
fn audited_speculative_paged_run_stays_clean() {
    let qm = anyprec_model(65);
    let mut plain = NativeBackend::new(Weights::Quant(&qm), 2);
    let (want, _) = serve(&mut plain, greedy_reqs(10)).unwrap();

    let mut spec = SpecBackend::paged(
        &qm,
        2,
        4,
        48,
        KvStoreKind::F32,
        SpecOptions::fixed(2, 4),
    )
    .expect("backend");
    spec.paged_kv_mut().expect("paged spec backend").set_audit(true);
    let (got, m) = serve(&mut spec, greedy_reqs(10)).unwrap();
    for (w, g) in want.iter().zip(&got) {
        assert_eq!(w.tokens, g.tokens, "req {}", w.id);
        assert_eq!(w.finish, g.finish);
    }
    assert!(m.spec_rounds > 0, "no speculation happened");

    let kv = spec.paged_kv_mut().expect("paged spec backend");
    assert!(kv.audits_run() > 0, "audit hooks never fired");
    kv.audit().expect("post-serve audit clean");
}
