"""Synthetic corpus generator — the WikiText-2 / C4 / PTB stand-ins.

Repro band is 0 (no model checkpoints, no datasets in this environment), so
per the substitution rule we synthesize three related-but-distinct text
distributions ("wiki2s", "c4s", "ptbs"). The generator is *integer-only*
(splitmix64 + integer cumulative-weight sampling) so the Rust port in
``rust/src/data/corpus.rs`` reproduces it byte-for-byte; a golden file
emitted by aot.py is compared in cargo tests.

Structure: a Zipfian vocabulary of pseudo-words with English-ish letter
frequencies, sentences of 4..12 words, and a deterministic bigram "chain"
(with probability 1/4 the next word is a fixed function of the previous
word) so a small trained transformer has real structure to learn — which is
what makes quantization-induced degradation measurable.
"""

from __future__ import annotations

import math

MASK64 = (1 << 64) - 1

# English letter frequencies (per mille, approximately) — fixed table shared
# with the Rust port.
LETTER_FREQ = [
    8167, 1492, 2782, 4253, 12702, 2228, 2015, 6094, 6966, 153, 772, 4025,
    2406, 6749, 7507, 1929, 95, 5987, 6327, 9056, 2758, 978, 2360, 150,
    1974, 74,
]


def splitmix64(state: int):
    """One step of splitmix64. Returns (new_state, output)."""
    state = (state + 0x9E3779B97F4A7C15) & MASK64
    z = state
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK64
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK64
    z = z ^ (z >> 31)
    return state, z


class Rng:
    """Tiny deterministic RNG shared (algorithmically) with Rust."""

    def __init__(self, seed: int):
        self.state = seed & MASK64

    def next_u64(self) -> int:
        self.state, z = splitmix64(self.state)
        return z

    def below(self, n: int) -> int:
        """Uniform integer in [0, n). Uses simple modulo (bias is irrelevant
        here and modulo keeps the Rust port trivial)."""
        return self.next_u64() % n


def cumsum(ws):
    out = []
    total = 0
    for w in ws:
        total += w
        out.append(total)
    return out, total


def sample_cum(rng: Rng, cum, total) -> int:
    r = rng.below(total)
    # binary search for first cum[i] > r
    lo, hi = 0, len(cum) - 1
    while lo < hi:
        mid = (lo + hi) // 2
        if cum[mid] > r:
            hi = mid
        else:
            lo = mid + 1
    return lo


def isqrt(n: int) -> int:
    return math.isqrt(n)


def zipf_weights(vocab: int, alpha2: int):
    """Integer Zipf-ish weights. alpha2 is twice the exponent, so
    alpha2=2 -> 1/k, alpha2=3 -> 1/k^1.5, alpha2=4 -> 1/k^2.
    All-integer so Rust matches exactly."""
    ws = []
    for k in range(1, vocab + 1):
        if alpha2 == 2:
            w = 10**9 // k
        elif alpha2 == 4:
            w = 10**9 // (k * k)
        else:  # alpha2 == 3
            w = 10**9 // isqrt(k * k * k)
        ws.append(max(w, 1))
    return ws


FLAVORS = {
    # name: (vocab, alpha2, chain_mul, chain_add, base_seed)
    "wiki2s": (512, 2, 17, 7, 0x57494B49),
    "c4s": (800, 3, 29, 11, 0x00C40C40),
    "ptbs": (300, 4, 13, 5, 0x00507442),
}


def build_vocab(flavor: str):
    vocab, _alpha2, _cm, _ca, base_seed = FLAVORS[flavor]
    rng = Rng(base_seed ^ 0xA5A5A5A5A5A5A5A5)
    cum_l, tot_l = cumsum(LETTER_FREQ)
    words = []
    seen = set()
    while len(words) < vocab:
        wlen = 2 + rng.below(7)
        w = bytes(
            ord("a") + sample_cum(rng, cum_l, tot_l) for _ in range(wlen)
        )
        if w in seen:
            continue
        seen.add(w)
        words.append(w)
    return words


def generate(flavor: str, split: str, nbytes: int) -> bytes:
    """Generate `nbytes` of deterministic text for (flavor, split)."""
    vocab, alpha2, cmul, cadd, base_seed = FLAVORS[flavor]
    split_off = {"train": 0, "valid": 1, "test": 2, "calib": 3}[split]
    words = build_vocab(flavor)
    ws = zipf_weights(vocab, alpha2)
    cum_w, tot_w = cumsum(ws)
    rng = Rng((base_seed * 2654435761 + split_off) & MASK64)

    out = bytearray()
    prev = 0
    while len(out) < nbytes:
        slen = 4 + rng.below(9)
        for i in range(slen):
            if i > 0:
                out.append(ord(" "))
            if i > 0 and rng.below(4) == 0:
                # deterministic bigram chain
                idx = (prev * cmul + cadd) % vocab
            else:
                idx = sample_cum(rng, cum_w, tot_w)
            out.extend(words[idx])
            prev = idx
            if i == slen - 2 and rng.below(5) == 0:
                out.append(ord(","))
        out.extend(b". ")
    return bytes(out[:nbytes])


def instruct_text(nbytes: int, seed: int = 0x1257) -> bytes:
    """Task-formatted text for the *instruct* fine-tune and the gsm-s /
    longbench-s analogues. Two patterns, mirrored by rust/src/data/tasks.rs:

      arithmetic:  "3+5=8."
      kv-recall:   "a=5;b=2;c=7;b?2."
    """
    rng = Rng(seed)
    out = bytearray()
    while len(out) < nbytes:
        if rng.below(2) == 0:
            a = rng.below(10)
            b = rng.below(10)
            s = a + b
            if s < 10:
                out.extend(f"{a}+{b}={s}. ".encode())
            else:
                out.extend(f"{a}+{b}=1{s-10}. ".encode())
        else:
            nkv = 2 + rng.below(11)
            keys = []
            vals = []
            for _ in range(nkv):
                k = chr(ord("a") + rng.below(26))
                v = rng.below(10)
                keys.append(k)
                vals.append(v)
                out.extend(f"{k}={v};".encode())
            qi = rng.below(nkv)
            # last binding of that key wins (matches rust eval)
            v = None
            for k2, v2 in zip(keys, vals):
                if k2 == keys[qi]:
                    v = v2
            out.extend(f"{keys[qi]}?{v}. ".encode())
    return bytes(out[:nbytes])


if __name__ == "__main__":
    for f in FLAVORS:
        print(f, generate(f, "train", 120))
    print(instruct_text(120))
