"""L2: the GANQ solver as a JAX graph (paper Algorithm 1), calling the L1
Pallas step kernel inside a `lax.scan` over columns.

AOT contract with the Rust coordinator:
  inputs : W [m, n] f32, L [n, n] f32 (lower Cholesky factor of the
           *preconditioned* H — Rust computes preconditioning + Cholesky
           natively, see rust/src/tensor/), T0 [m, 2^N] f32
  outputs: Q [m, n] i32, T [m, 2^N] f32, errs [K] f32 (per-iteration
           layer error, for the monotonicity property test)

No jnp.linalg anywhere: on CPU those lower to jaxlib LAPACK custom-calls
that xla_extension 0.5.1 (the runtime under the Rust `xla` crate) does not
register. The 2^N x 2^N T-step solve is an unrolled Cholesky written in
plain jnp (K <= 16, so the unroll is tiny).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernels.ganq_step import ganq_step


def chol_solve_small(a, b):
    """Batched SPD solve via unrolled Cholesky. a [m, K, K], b [m, K].
    K is a static small constant (8 or 16). Returns x with a @ x = b."""
    k = a.shape[-1]
    # Cholesky (unrolled; traced once)
    l = jnp.zeros_like(a)
    for j in range(k):
        s = a[:, j, j] - jnp.sum(l[:, j, :j] ** 2, axis=-1) if j else a[:, j, j]
        djj = jnp.sqrt(jnp.maximum(s, 1e-20))
        l = l.at[:, j, j].set(djj)
        if j + 1 < k:
            if j:
                dot = jnp.einsum("mik,mk->mi", l[:, j + 1 :, :j], l[:, j, :j])
            else:
                dot = 0.0
            l = l.at[:, j + 1 :, j].set((a[:, j + 1 :, j] - dot) / djj[:, None])
    # forward substitution L y = b
    y = jnp.zeros_like(b)
    for j in range(k):
        dot = jnp.einsum("mk,mk->m", l[:, j, :j], y[:, :j]) if j else 0.0
        y = y.at[:, j].set((b[:, j] - dot) / l[:, j, j])
    # back substitution L^T x = y
    x = jnp.zeros_like(b)
    for j in range(k - 1, -1, -1):
        if j + 1 < k:
            dot = jnp.einsum("mk,mk->m", l[:, j + 1 :, j], x[:, j + 1 :])
        else:
            dot = 0.0
        x = x.at[:, j].set((y[:, j] - dot) / l[:, j, j])
    return x


def sstep(w, l, t, use_pallas: bool = True):
    """Batched back-substitution S-step. w [m,n], l [n,n] lower, t [m,K].
    Returns q [m, n] i32. Columns processed n-1 .. 0 via lax.scan
    (reverse=True); the argmin/gather hot spot is the L1 Pallas kernel.

    AOT COMPATIBILITY NOTE: per-column data (w column, L row, L diagonal
    entry, column index) is threaded through the scan as *xs* rather than
    indexed out of loop-invariant arrays inside the body. xla_extension
    0.5.1 (the runtime under the Rust `xla` crate) miscompiles while-loop
    bodies that dynamic-slice/gather loop-INVARIANT operands at a
    *data-dependent* index (see rust/tests/bisect_probe.rs: probes v2/v3/
    v5/v6/v7 broken, v1/v4/v8/v9 correct). Counter-driven xs consumption
    and carry-indexed gathers execute correctly on both runtimes."""
    m, n = w.shape
    wcols = w.T  # [n, m]
    ldiag = jnp.diagonal(l)  # [n]
    js = jnp.arange(n, dtype=jnp.int32)

    def body(acc, xs):
        wj, lrow, ljj, j = xs
        accj = jnp.take_along_axis(
            acc, jnp.full((m, 1), j, jnp.int32), axis=1
        )[:, 0]
        if use_pallas:
            idx, r = ganq_step(wj, accj, ljj[None], t)
        else:
            e = wj + accj / ljj
            idx = jnp.argmin(jnp.abs(e[:, None] - t), axis=1).astype(jnp.int32)
            r = wj - jnp.take_along_axis(t, idx[:, None], axis=1)[:, 0]
        acc = acc + r[:, None] * lrow[None, :]
        return acc, idx

    _, idxs = jax.lax.scan(
        body,
        jnp.zeros((m, n), w.dtype),
        (wcols, l, ldiag, js),
        reverse=True,
    )
    # reverse=True stacks ys at forward positions: idxs[j] = column j
    return idxs.T


def tstep(w, h, q, t_prev, eps_rel: float = 1e-6):
    """Closed-form codebook update (paper eq. 7), batched over rows.
    w [m,n], h [n,n], q [m,n] i32, t_prev [m,K]."""
    m, n = w.shape
    k = t_prev.shape[1]
    onehot = jax.nn.one_hot(q, k, dtype=w.dtype)  # [m, n, K]
    g = w @ h  # [m, n]
    num = jnp.einsum("mn,mns->ms", g, onehot)  # [m, K]
    hs = jnp.einsum("nk,mks->mns", h, onehot)  # [m, n, K]
    a = jnp.einsum("mns,mnt->mst", onehot, hs)  # [m, K, K]
    counts = onehot.sum(axis=1)  # [m, K]
    tr = jnp.einsum("mss->m", a)
    eps = eps_rel * jnp.maximum(tr / k, 1e-12)
    a_reg = a + eps[:, None, None] * jnp.eye(k, dtype=w.dtype)[None]
    sol = chol_solve_small(a_reg, num)
    return jnp.where(counts > 0, sol, t_prev)


def layer_error(w, w_hat, h):
    d = w - w_hat
    return jnp.einsum("ij,jk,ik->", d, h, d)


def ganq_solve(w, l, t0, iters: int, use_pallas: bool = True):
    """Full GANQ: K alternating iterations + final S-step.
    Returns (q, t, errs[K])."""
    m, n = w.shape
    h = l @ l.T  # preconditioned H, reconstructed from its factor

    def it(carry, _):
        t, _q = carry
        q = sstep(w, l, t, use_pallas)
        t = tstep(w, h, q, t)
        w_hat = jnp.take_along_axis(t, q, axis=1)
        err = layer_error(w, w_hat, h)
        return (t, q), err

    q0 = jnp.zeros((m, n), jnp.int32)
    (t, _), errs = jax.lax.scan(it, (t0, q0), None, length=iters)
    q = sstep(w, l, t, use_pallas)
    return q, t, errs


def build_ganq_fn(m: int, n: int, bits: int, iters: int = 10,
                  use_pallas: bool = True):
    """AOT entry point for a given layer shape."""
    k = 2**bits

    def f(w, l, t0):
        return ganq_solve(w, l, t0, iters, use_pallas)

    shapes = [
        jax.ShapeDtypeStruct((m, n), jnp.float32),
        jax.ShapeDtypeStruct((n, n), jnp.float32),
        jax.ShapeDtypeStruct((m, k), jnp.float32),
    ]
    return f, shapes
