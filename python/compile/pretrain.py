"""Build-time pretraining of the model family on the synthetic corpus.

Repro band is 0: no OPT/LLaMA checkpoints exist in this environment, so the
"small real models" the pipeline quantizes are trained here, from scratch,
on the wiki2s synthetic corpus (DESIGN.md substitution table). Instruct
variants are fine-tuned from their base on a corpus/task-text mixture so the
gsm-s / longbench-s analogues (Table 4) measure something real.

Runs once under `make artifacts`; weights land in artifacts/weights/<model>/
as raw little-endian f32 (`weights.bin`) plus a JSON tensor index. The loss
curve is logged to train_log.json and summarized in EXPERIMENTS.md.
"""

from __future__ import annotations

import json
import os
import time

import numpy as np
import jax
import jax.numpy as jnp

from . import corpus, model

TRAIN_STEPS = {
    "opt-micro": 500,
    "opt-mini": 600,
    "opt-small": 700,
    "opt-med": 700,
    # TTFT-bench model: pos_emb beyond SEQ stays near init (training runs
    # at SEQ=128), which is fine — the long-context serving graphs only
    # need real, loadable weights, not long-range quality
    "opt-longctx": 300,
}
BATCH = {
    "opt-micro": 32,
    "opt-mini": 32,
    "opt-small": 24,
    "opt-med": 16,
    "opt-longctx": 32,
}
INSTRUCT_STEPS = 900
SEQ = 128
CORPUS_BYTES = 1_500_000


def adam_update(params, grads, mstate, vstate, step, lr, b1=0.9, b2=0.99,
                eps=1e-8, wd=0.01):
    def upd(p, g, mm, vv):
        mm = b1 * mm + (1 - b1) * g
        vv = b2 * vv + (1 - b2) * g * g
        mhat = mm / (1 - b1**step)
        vhat = vv / (1 - b2**step)
        return p - lr * (mhat / (jnp.sqrt(vhat) + eps) + wd * p), mm, vv

    out = jax.tree_util.tree_map(upd, params, grads, mstate, vstate)
    new_p = jax.tree_util.tree_map(lambda t: t[0], out,
                                   is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree_util.tree_map(lambda t: t[1], out,
                                   is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree_util.tree_map(lambda t: t[2], out,
                                   is_leaf=lambda t: isinstance(t, tuple))
    return new_p, new_m, new_v


def batches(data: np.ndarray, bs: int, seq: int, rng: np.random.RandomState):
    n = len(data) - seq - 1
    while True:
        idx = rng.randint(0, n, bs)
        yield np.stack([data[i : i + seq] for i in idx]).astype(np.int32)


def train_model(name: str, out_dir: str, base_weights: dict | None = None,
                log=print) -> dict:
    cfg = model.config_for(name)
    is_instruct = name in model.INSTRUCT_VARIANTS
    steps = INSTRUCT_STEPS if is_instruct else TRAIN_STEPS[name]
    bs = BATCH[model.INSTRUCT_VARIANTS.get(name, name)]

    text = corpus.generate("wiki2s", "train", CORPUS_BYTES)
    data = np.frombuffer(text, dtype=np.uint8)
    if is_instruct:
        itext = corpus.instruct_text(CORPUS_BYTES // 2)
        idata = np.frombuffer(itext, dtype=np.uint8)

    if base_weights is not None:
        params = {k: jnp.array(v) for k, v in base_weights.items()}
    else:
        params = {k: jnp.array(v) for k, v in model.init_params(7, cfg).items()}

    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    mstate, vstate = zeros, jax.tree_util.tree_map(jnp.zeros_like, params)

    def loss_fn(p, toks):
        return model.nll_sum(p, toks, cfg) / (toks.shape[0] * (SEQ - 1))

    @jax.jit
    def step_fn(p, m, v, toks, stepno, lr):
        loss, grads = jax.value_and_grad(loss_fn)(p, toks)
        p, m, v = adam_update(p, grads, m, v, stepno, lr)
        return p, m, v, loss

    rng = np.random.RandomState(0xBEEF)
    gen = batches(data, bs, SEQ, rng)
    if is_instruct:
        igen = batches(idata, bs, SEQ, rng)

    base_lr = 3e-3 if not is_instruct else 2e-3
    warmup = 20
    hist = []
    t0 = time.time()
    for s in range(1, steps + 1):
        lr = base_lr * min(1.0, s / warmup)
        lr = lr * 0.5 * (1 + np.cos(np.pi * s / steps))
        # instruct fine-tune: 3/4 task-format batches, 1/4 corpus replay
        toks = next(igen) if (is_instruct and s % 4 != 0) else next(gen)
        params, mstate, vstate, loss = step_fn(
            params, mstate, vstate, toks, s, lr
        )
        if s % 25 == 0 or s == 1:
            hist.append({"step": s, "loss": float(loss)})
            log(f"  [{name}] step {s}/{steps} loss {float(loss):.4f}")

    # held-out perplexity
    vtext = corpus.generate("wiki2s", "valid", 200_000)
    vdata = np.frombuffer(vtext, dtype=np.uint8)
    vgen = batches(vdata, bs, SEQ, np.random.RandomState(1))
    tot, cnt = 0.0, 0
    nll_j = jax.jit(lambda p, t: model.nll_sum(p, t, cfg))
    for _ in range(8):
        toks = next(vgen)
        tot += float(nll_j(params, toks))
        cnt += toks.shape[0] * (SEQ - 1)
    ppl = float(np.exp(tot / cnt))
    log(f"  [{name}] valid ppl {ppl:.3f}  ({time.time()-t0:.0f}s)")

    params_np = {k: np.asarray(v, np.float32) for k, v in params.items()}
    save_weights(name, cfg, params_np, out_dir, hist, ppl)
    return params_np


def save_weights(name, cfg, params_np, out_dir, hist, ppl):
    mdir = os.path.join(out_dir, "weights", name)
    os.makedirs(mdir, exist_ok=True)
    spec = model.param_spec(cfg)
    tensors = []
    offset = 0
    with open(os.path.join(mdir, "weights.bin"), "wb") as f:
        for pname, shape in spec:
            arr = params_np[pname].astype("<f4")
            f.write(arr.tobytes())
            tensors.append(
                {
                    "name": pname,
                    "shape": list(shape),
                    "offset": offset,
                    "numel": int(arr.size),
                }
            )
            offset += arr.size * 4
    with open(os.path.join(mdir, "weights.json"), "w") as f:
        json.dump({"model": name, "tensors": tensors}, f)
    with open(os.path.join(mdir, "train_log.json"), "w") as f:
        json.dump({"loss_curve": hist, "valid_ppl": ppl}, f)


def load_weights(name: str, out_dir: str) -> dict | None:
    mdir = os.path.join(out_dir, "weights", name)
    jpath = os.path.join(mdir, "weights.json")
    bpath = os.path.join(mdir, "weights.bin")
    if not (os.path.exists(jpath) and os.path.exists(bpath)):
        return None
    with open(jpath) as f:
        index = json.load(f)
    raw = np.fromfile(bpath, dtype="<f4")
    params = {}
    for t in index["tensors"]:
        off = t["offset"] // 4
        params[t["name"]] = raw[off : off + t["numel"]].reshape(t["shape"])
    return params


def ensure_all(out_dir: str, log=print) -> dict:
    """Train any missing model; returns {name: params}."""
    all_params = {}
    for name in model.CONFIGS:
        p = load_weights(name, out_dir)
        if p is None:
            log(f"training {name} ...")
            p = train_model(name, out_dir, log=log)
        all_params[name] = p
    for name, base in model.INSTRUCT_VARIANTS.items():
        p = load_weights(name, out_dir)
        if p is None:
            log(f"fine-tuning {name} from {base} ...")
            p = train_model(name, out_dir, base_weights=all_params[base],
                            log=log)
        all_params[name] = p
    return all_params
