"""L2: OPT-style decoder-only transformer in JAX — FP32 and LUT-quantized
variants, prefill/decode graphs with explicit KV cache, and the NLL graph
used for perplexity evaluation.

All graphs take weights as *arguments* (never baked constants) so one
compiled artifact serves every quantization method: the Rust pipeline feeds
either original or reconstructed weights into `nll_fp32_*`, and packed
(Q, T) pairs into the `*_lut*` serving graphs.

Parameter ordering is canonical (`param_spec`); the AOT manifest records
the exact argument list per graph so the Rust runtime can marshal literals
without guessing.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from .kernels.ref import lut_matmul_ref
from .kernels.lut_gemm import lut_gemm

# model family — the OPT-125M..6.7B / LLaMA-7B stand-ins (DESIGN.md
# substitution table). byte-level vocab.
CONFIGS = {
    "opt-micro": dict(d=64, layers=2, heads=2, ff=256, ctx=128, vocab=256),
    "opt-mini": dict(d=96, layers=3, heads=4, ff=384, ctx=128, vocab=256),
    "opt-small": dict(d=128, layers=4, heads=4, ff=512, ctx=128, vocab=256),
    "opt-med": dict(d=192, layers=6, heads=6, ff=768, ctx=128, vocab=256),
    # long-context serving stand-in: shares opt-mini's linear shapes (no
    # extra GANQ solver graphs) but a ctx that makes 2048-token prompts —
    # and therefore the chunked-prefill TTFT acceptance — real on the AOT
    # path (benches/prefill_ttft.rs HLO series)
    "opt-longctx": dict(d=96, layers=2, heads=4, ff=384, ctx=2176,
                        vocab=256),
}
# instruct variants share the base architecture (fine-tuned on task text)
INSTRUCT_VARIANTS = {
    "opt-mini-instruct": "opt-mini",
    "opt-small-instruct": "opt-small",
}


def config_for(model: str) -> dict:
    if model in CONFIGS:
        return CONFIGS[model]
    return CONFIGS[INSTRUCT_VARIANTS[model]]


# the six quantizable linears per decoder layer (the paper quantizes decoder
# weights; embeddings / layernorms / biases stay FP)
QUANT_LINEARS = ["wq", "wk", "wv", "wo", "w1", "w2"]


def linear_shapes(cfg) -> list:
    """[(name, m, n)] for every quantizable linear, in canonical order."""
    d, ff = cfg["d"], cfg["ff"]
    out = []
    for li in range(cfg["layers"]):
        for nm in ["wq", "wk", "wv", "wo"]:
            out.append((f"l{li}.{nm}", d, d))
        out.append((f"l{li}.w1", ff, d))
        out.append((f"l{li}.w2", d, ff))
    return out


def param_spec(cfg) -> list:
    """Canonical ordered [(name, shape)] of all FP32 parameters."""
    d, ff, v, ctx = cfg["d"], cfg["ff"], cfg["vocab"], cfg["ctx"]
    spec = [("tok_emb", (v, d)), ("pos_emb", (ctx, d))]
    for li in range(cfg["layers"]):
        p = f"l{li}."
        spec += [
            (p + "ln1_g", (d,)),
            (p + "ln1_b", (d,)),
            (p + "wq", (d, d)),
            (p + "bq", (d,)),
            (p + "wk", (d, d)),
            (p + "bk", (d,)),
            (p + "wv", (d, d)),
            (p + "bv", (d,)),
            (p + "wo", (d, d)),
            (p + "bo", (d,)),
            (p + "ln2_g", (d,)),
            (p + "ln2_b", (d,)),
            (p + "w1", (ff, d)),
            (p + "b1", (ff,)),
            (p + "w2", (d, ff)),
            (p + "b2", (d,)),
        ]
    spec += [("ln_f_g", (d,)), ("ln_f_b", (d,))]
    return spec


def lut_param_spec(cfg, bits: int) -> list:
    """Param spec for the LUT serving graphs: every quantizable linear W is
    replaced by (W.qp uint8 [m, n//2], W.t f32 [m, 2^bits])."""
    k = 2**bits
    qnames = {nm for nm, _m, _n in linear_shapes(cfg)}
    spec = []
    for name, shape in param_spec(cfg):
        if name in qnames:
            m, n = shape
            spec.append((name + ".qp", (m, n // 2)))
            spec.append((name + ".t", (m, k)))
        else:
            spec.append((name, shape))
    return spec


def init_params(seed: int, cfg) -> dict:
    rng = np.random.RandomState(seed)
    params = {}
    for name, shape in param_spec(cfg):
        base = name.split(".")[-1]
        if base.endswith("_g"):
            params[name] = np.ones(shape, np.float32)
        elif base.endswith("_b") or base.startswith("b"):
            params[name] = np.zeros(shape, np.float32)
        elif base in ("wo", "w2"):
            # residual-branch projections scaled down (GPT-2 style)
            std = 0.08 / np.sqrt(2.0 * cfg["layers"])
            params[name] = rng.normal(0, std, shape).astype(np.float32)
        else:
            params[name] = rng.normal(0, 0.08, shape).astype(np.float32)
    return params


def params_to_list(params: dict, spec) -> list:
    return [params[name] for name, _ in spec]


def list_to_params(vals, spec) -> dict:
    return {name: v for (name, _), v in zip(spec, vals)}


# ---------------------------------------------------------------------------
# building blocks
# ---------------------------------------------------------------------------


def layer_norm(x, g, b, eps=1e-5):
    mu = x.mean(-1, keepdims=True)
    var = ((x - mu) ** 2).mean(-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * g + b


def gelu(x):
    # tanh approximation — avoids any erf custom-call question entirely
    return 0.5 * x * (1.0 + jnp.tanh(0.7978845608 * (x + 0.044715 * x**3)))


def make_linear(params, name, mode):
    """Returns f(x2d [p, n]) -> [p, m] for the named quantizable linear.
    mode: 'fp32' (plain W), 'lut' (jnp gather path), 'pallas' (L1 kernel)."""
    if mode == "fp32":
        w = params[name]
        return lambda x: x @ w.T
    qp, t = params[name + ".qp"], params[name + ".t"]
    if mode == "lut":
        return lambda x: lut_matmul_ref(x, qp, t)
    kbits = int(np.log2(t.shape[1]))

    def f(x):
        p = x.shape[0]
        bp = 8 if p % 8 == 0 else (p if p < 8 else 1)
        m = qp.shape[0]
        bm = 64 if m % 64 == 0 else m
        return lut_gemm(x, qp, t, kbits=kbits, block_p=bp, block_m=bm)

    return f


def block_fwd(params, li, x, cfg, mode, mask, kv=None):
    """One decoder block. x: [B, S, d].

    If kv is given as (kc, vc, pos) (caches [B, h, ctx, hd], pos [B]) this is
    a decode step (S == 1): new K/V are scattered at per-slot positions via a
    one-hot blend and attention runs over the cache. If pos is [B, S] this is
    a positioned prefill chunk: token s of slot b lands at cache position
    pos[b, s] (positions outside [0, ctx) are dropped by the one-hot — the
    "pos-masked scratch" convention padding uses), and query s attends to
    cache positions <= pos[b, s]. Otherwise: causal self-attention over x;
    returns (x, k, v) so prefill can seed the cache.
    """
    d, h = cfg["d"], cfg["heads"]
    hd = d // h
    p = f"l{li}."
    B, S, _ = x.shape

    def lin(name, y2d):
        f = make_linear(params, p + name, mode)
        return f(y2d) + params[p + "b" + name[1:]]

    a = layer_norm(x, params[p + "ln1_g"], params[p + "ln1_b"])
    a2 = a.reshape(B * S, d)
    q = lin("wq", a2).reshape(B, S, h, hd).transpose(0, 2, 1, 3)
    k = lin("wk", a2).reshape(B, S, h, hd).transpose(0, 2, 1, 3)
    v = lin("wv", a2).reshape(B, S, h, hd).transpose(0, 2, 1, 3)

    if kv is None:
        scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) / np.sqrt(hd)
        scores = jnp.where(mask, scores, -1e9)
        att = jax.nn.softmax(scores, axis=-1)
        o = jnp.einsum("bhqk,bhkd->bhqd", att, v)
        kc_out, vc_out = k, v
    elif kv[2].ndim == 2:
        kc, vc, posm = kv  # posm [B, S]: absolute position per chunk token
        ctx = kc.shape[2]
        oh = jax.nn.one_hot(posm, ctx, dtype=x.dtype)  # [B, S, ctx]
        wm = oh.sum(axis=1)  # [B, ctx] write mask (chunk positions distinct)
        kc_out = kc * (1.0 - wm[:, None, :, None]) + jnp.einsum(
            "bst,bhsd->bhtd", oh, k
        )
        vc_out = vc * (1.0 - wm[:, None, :, None]) + jnp.einsum(
            "bst,bhsd->bhtd", oh, v
        )
        scores = jnp.einsum("bhqd,bhkd->bhqk", q, kc_out) / np.sqrt(hd)
        valid = (
            jnp.arange(ctx)[None, None, None, :] <= posm[:, None, :, None]
        )
        scores = jnp.where(valid, scores, -1e9)
        att = jax.nn.softmax(scores, axis=-1)
        o = jnp.einsum("bhqk,bhkd->bhqd", att, vc_out)
    else:
        kc, vc, posv = kv
        ctx = kc.shape[2]
        oh = jax.nn.one_hot(posv, ctx, dtype=x.dtype)  # [B, ctx]
        ohb = oh[:, None, :, None]  # [B, 1, ctx, 1]
        kc_out = kc * (1.0 - ohb) + ohb * k  # k: [B, h, 1, hd] broadcast
        vc_out = vc * (1.0 - ohb) + ohb * v
        scores = jnp.einsum("bhqd,bhkd->bhqk", q, kc_out) / np.sqrt(hd)
        valid = (
            jnp.arange(ctx)[None, None, None, :] <= posv[:, None, None, None]
        )
        scores = jnp.where(valid, scores, -1e9)
        att = jax.nn.softmax(scores, axis=-1)
        o = jnp.einsum("bhqk,bhkd->bhqd", att, vc_out)

    o = o.transpose(0, 2, 1, 3).reshape(B * S, d)
    x = x + lin("wo", o).reshape(B, S, d)

    mlp_in = layer_norm(x, params[p + "ln2_g"], params[p + "ln2_b"])
    hmid = gelu(lin("w1", mlp_in.reshape(B * S, d)))
    x = x + lin("w2", hmid).reshape(B, S, d)
    return x, kc_out, vc_out


def fwd(params, tokens, cfg, mode="fp32"):
    """Full causal forward. tokens [B, S] i32 -> logits [B, S, V]."""
    B, S = tokens.shape
    x = params["tok_emb"][tokens] + params["pos_emb"][None, :S]
    mask = jnp.tril(jnp.ones((S, S), bool))[None, None]
    kcs, vcs = [], []
    for li in range(cfg["layers"]):
        x, kc, vc = block_fwd(params, li, x, cfg, mode, mask)
        kcs.append(kc)
        vcs.append(vc)
    x = layer_norm(x, params["ln_f_g"], params["ln_f_b"])
    logits = x @ params["tok_emb"].T  # tied head
    return logits, kcs, vcs


def nll_sum(params, tokens, cfg, mode="fp32"):
    """Sum of next-token negative log-likelihoods (f32 scalar). The Rust
    side aggregates sums/counts across batches to report perplexity."""
    logits, _, _ = fwd(params, tokens, cfg, mode)
    lp = jax.nn.log_softmax(logits[:, :-1], axis=-1)
    tgt = tokens[:, 1:]
    nll = -jnp.take_along_axis(lp, tgt[..., None], axis=-1)[..., 0]
    return jnp.sum(nll)


def prefill(params, tokens, cfg, mode="fp32"):
    """tokens [B, S] -> (last-position logits [B, V], kcache, vcache) with
    caches shaped [L, B, h, ctx, hd], filled at positions 0..S-1."""
    B, S = tokens.shape
    d, h, ctx = cfg["d"], cfg["heads"], cfg["ctx"]
    hd = d // h
    logits, kcs, vcs = fwd(params, tokens, cfg, mode)
    kcache = jnp.zeros((cfg["layers"], B, h, ctx, hd), jnp.float32)
    vcache = jnp.zeros_like(kcache)
    for li in range(cfg["layers"]):
        kcache = kcache.at[li, :, :, :S].set(kcs[li])
        vcache = vcache.at[li, :, :, :S].set(vcs[li])
    return logits[:, -1], kcache, vcache


def decode_step(params, tok, pos, kcache, vcache, cfg, mode="fp32"):
    """One generation step with per-slot positions (continuous batching).

    tok [B] i32, pos [B] i32, caches [L, B, h, ctx, hd]
    -> (logits [B, V], kcache', vcache')."""
    kcache = jnp.asarray(kcache)
    vcache = jnp.asarray(vcache)
    x = params["tok_emb"][tok][:, None, :] + params["pos_emb"][pos][:, None, :]
    kc_new = kcache
    vc_new = vcache
    for li in range(cfg["layers"]):
        x, kc, vc = block_fwd(
            params, li, x, cfg, mode, None, kv=(kcache[li], vcache[li], pos)
        )
        kc_new = kc_new.at[li].set(kc)
        vc_new = vc_new.at[li].set(vc)
    x = layer_norm(x, params["ln_f_g"], params["ln_f_b"])
    logits = (x @ params["tok_emb"].T)[:, 0]
    return logits, kc_new, vc_new


def prefill_chunk(params, tokens, pos, last, kcache, vcache, cfg,
                  mode="fp32"):
    """One positioned chunked-prefill step (continuous batching).

    tokens [B, C] i32, pos [B] i32 (absolute position of tokens[:, 0]),
    last [B] i32 (in-chunk index of the row whose logits to return),
    caches [L, B, h, ctx, hd] -> (logits [B, V], kcache', vcache').

    Token s of slot b lands at cache position pos[b] + s; the causal
    in-chunk mask is the per-token offset (query s sees cache positions
    <= pos[b] + s), so the chunk is exactly S sequential decode steps in
    one dispatch. Ragged tails are served by *end-padding* with scratch
    tokens: a padded position's key/value rows are either overwritten
    before any masked read can see them (they sit strictly after every
    real query's window and after the slot's live position) or dropped
    entirely when pos[b] + s falls outside [0, ctx) — the one-hot write
    mask is zero there. `last` points the logits gather at the final
    *real* token, so padding never pollutes the returned row."""
    kcache = jnp.asarray(kcache)
    vcache = jnp.asarray(vcache)
    B, C = tokens.shape
    ctx = cfg["ctx"]
    posm = pos[:, None] + jnp.arange(C, dtype=jnp.int32)[None, :]
    x = (
        params["tok_emb"][tokens]
        + params["pos_emb"][jnp.clip(posm, 0, ctx - 1)]
    )
    kc_new = kcache
    vc_new = vcache
    for li in range(cfg["layers"]):
        x, kc, vc = block_fwd(
            params, li, x, cfg, mode, None,
            kv=(kcache[li], vcache[li], posm),
        )
        kc_new = kc_new.at[li].set(kc)
        vc_new = vc_new.at[li].set(vc)
    x = layer_norm(x, params["ln_f_g"], params["ln_f_b"])
    rows = jnp.take_along_axis(
        x, jnp.clip(last, 0, C - 1)[:, None, None], axis=1
    )[:, 0]
    logits = rows @ params["tok_emb"].T
    return logits, kc_new, vc_new


# ---------------------------------------------------------------------------
# graph builders (arg-list entry points for AOT lowering)
# ---------------------------------------------------------------------------


def spec_for(cfg, mode: str, bits: int = 4):
    return param_spec(cfg) if mode == "fp32" else lut_param_spec(cfg, bits)


def build_nll_fn(cfg, mode="fp32", bits=4):
    spec = spec_for(cfg, mode, bits)

    def f(tokens, *weights):
        params = list_to_params(weights, spec)
        return (nll_sum(params, tokens, cfg, mode),)

    return f, spec


def build_prefill_fn(cfg, mode="fp32", bits=4):
    """Positioned chunked-prefill graph (`prefill_{fmt}_{model}_b{B}_c{C}`):
    advances every slot by a fixed C-token chunk at per-slot positions —
    the serving analogue of `decode_step` for prompt runs."""
    spec = spec_for(cfg, mode, bits)

    def f(tokens, pos, last, kcache, vcache, *weights):
        params = list_to_params(weights, spec)
        return prefill_chunk(
            params, tokens, pos, last, kcache, vcache, cfg, mode
        )

    return f, spec


def build_decode_fn(cfg, mode="fp32", bits=4):
    spec = spec_for(cfg, mode, bits)

    def f(tok, pos, kcache, vcache, *weights):
        params = list_to_params(weights, spec)
        return decode_step(params, tok, pos, kcache, vcache, cfg, mode)

    return f, spec
