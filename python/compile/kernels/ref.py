"""Pure-jnp / numpy correctness oracles for the Pallas kernels and the GANQ
solver. Everything here is the *reference semantics*; the Pallas kernels in
lut_gemm.py / ganq_step.py and the Rust-native implementations in
rust/src/quant/ are validated against these (pytest + golden fixtures).
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# 4-bit / 3-bit code packing (nibble container)
# ---------------------------------------------------------------------------
# Byte j of a packed row holds the codes of columns 2j (low nibble) and
# 2j+1 (high nibble). 3-bit codes use the same container (values 0..7); the
# Rust native serving path additionally supports dense 3-bit packing — the
# HLO graphs use the nibble container for both (documented in DESIGN.md).


def pack_nibbles(q: np.ndarray) -> np.ndarray:
    """q: [m, n] integer codes in 0..15 -> packed uint8 [m, n//2]."""
    m, n = q.shape
    assert n % 2 == 0, "n must be even for nibble packing"
    q = q.astype(np.uint8)
    lo = q[:, 0::2]
    hi = q[:, 1::2]
    return (lo | (hi << 4)).astype(np.uint8)


def unpack_nibbles_np(qp: np.ndarray, n: int) -> np.ndarray:
    m = qp.shape[0]
    lo = qp & 0xF
    hi = qp >> 4
    out = np.empty((m, n), dtype=np.int32)
    out[:, 0::2] = lo
    out[:, 1::2] = hi
    return out


def unpack_nibbles(qp, n: int):
    """jnp version usable inside lowered graphs. qp: uint8 [m, n//2]."""
    lo = (qp & 0xF).astype(jnp.int32)
    hi = (qp >> 4).astype(jnp.int32)
    return jnp.stack([lo, hi], axis=-1).reshape(qp.shape[0], n)


def pack3(q: np.ndarray) -> np.ndarray:
    """Dense 3-bit packing: 8 codes -> 3 bytes (row-padded to multiple of 8).
    Only used by the Rust native LUT path; provided here for the golden
    fixture + cross-language tests."""
    m, n = q.shape
    npad = (n + 7) // 8 * 8
    qq = np.zeros((m, npad), dtype=np.uint32)
    qq[:, :n] = q.astype(np.uint32)
    out = np.zeros((m, npad // 8 * 3), dtype=np.uint8)
    for g in range(npad // 8):
        v = np.zeros(m, dtype=np.uint32)
        for i in range(8):
            v |= qq[:, g * 8 + i] << (3 * i)
        out[:, 3 * g + 0] = v & 0xFF
        out[:, 3 * g + 1] = (v >> 8) & 0xFF
        out[:, 3 * g + 2] = (v >> 16) & 0xFF
    return out


def unpack3(qp: np.ndarray, n: int) -> np.ndarray:
    m = qp.shape[0]
    ngroups = qp.shape[1] // 3
    out = np.zeros((m, ngroups * 8), dtype=np.int32)
    for g in range(ngroups):
        v = (
            qp[:, 3 * g].astype(np.uint32)
            | (qp[:, 3 * g + 1].astype(np.uint32) << 8)
            | (qp[:, 3 * g + 2].astype(np.uint32) << 16)
        )
        for i in range(8):
            out[:, g * 8 + i] = (v >> (3 * i)) & 0x7
    return out[:, :n]


# ---------------------------------------------------------------------------
# Any-precision bit-plane layout (nested export)
# ---------------------------------------------------------------------------
# Plane p holds bit p of every code (p = 0 is the LSB), each row bitpacked
# into ceil(n/8) bytes LSB-first: bit (j % 8) of byte (j // 8) is column j.
# The w-bit model is the top-w planes — code_w = code >> (bits - w) — with
# a per-width codebook. Mirrors rust/src/quant/anyprec.rs exactly.


def pack_bitplanes(q: np.ndarray, bits: int) -> list[np.ndarray]:
    """q: [m, n] integer codes in 0..2^bits-1 -> `bits` uint8 planes of
    shape [m, ceil(n/8)], plane p holding bit p."""
    m, n = q.shape
    rowb = (n + 7) // 8
    q = q.astype(np.uint32)
    planes = []
    for p in range(bits):
        bit = np.zeros((m, rowb * 8), dtype=np.uint8)
        bit[:, :n] = (q >> p) & 1
        plane = np.zeros((m, rowb), dtype=np.uint8)
        for k in range(8):
            plane |= bit[:, k::8] << k
        planes.append(plane)
    return planes


def unpack_bitplanes(
    planes: list[np.ndarray], n: int, w: int | None = None
) -> np.ndarray:
    """Top-`w` plane slice back to codes: code_w = parent >> (bits - w).
    w=None reads the full-width parent codes."""
    bits = len(planes)
    w = bits if w is None else w
    m = planes[0].shape[0]
    out = np.zeros((m, n), dtype=np.int32)
    for b in range(w):
        plane = planes[bits - w + b]
        bit = np.zeros((m, plane.shape[1] * 8), dtype=np.int32)
        for k in range(8):
            bit[:, k::8] = (plane >> k) & 1
        out |= bit[:, :n] << b
    return out


def anyprec_merge_codebook_np(t: np.ndarray, q: np.ndarray) -> np.ndarray:
    """One merge level of the upgrade path: the (w+1)-bit codebook t
    [m, 2K] and codes q [m, n] at width w+1 -> the w-bit init codebook
    [m, K]. Children 2c/2c+1 pair count-weighted (bucket mean of the
    children's reconstruction = the identity-Hessian optimum); empty
    pairs fall back to the midpoint. Matches quant::anyprec::merge_level."""
    m, k2 = t.shape
    out = np.zeros((m, k2 // 2), dtype=t.dtype)
    for i in range(m):
        counts = np.bincount(q[i], minlength=k2).astype(np.float64)
        n0, n1 = counts[0::2], counts[1::2]
        tot = n0 + n1
        weighted = (n0 * t[i, 0::2] + n1 * t[i, 1::2]) / np.maximum(tot, 1)
        mid = 0.5 * (t[i, 0::2] + t[i, 1::2])
        out[i] = np.where(tot > 0, weighted, mid)
    return out


def anyprec_codebooks_np(
    t: np.ndarray, q: np.ndarray, bits: int, widths: list[int]
) -> dict[int, np.ndarray]:
    """Per-width codebooks for the nested store, seedless path (no
    calibration re-fit): repeated count-weighted merges from the parent
    codebook down to min(widths). Matches BitPlaneStore::nest."""
    books = {bits: t.astype(np.float32)}
    cur = t.astype(np.float64)
    for wd in range(bits - 1, min(widths) - 1, -1):
        q_wd1 = (q >> (bits - (wd + 1))).astype(np.int64)
        cur = anyprec_merge_codebook_np(cur, q_wd1)
        if wd in widths:
            books[wd] = cur.astype(np.float32)
    return {w: books[w] for w in sorted(widths)}


# ---------------------------------------------------------------------------
# LUT-based mpGEMM reference
# ---------------------------------------------------------------------------


def lut_dequant(qp, t, n: int):
    """Reconstruct W_hat [m, n] from packed codes + per-row codebook."""
    idx = unpack_nibbles(qp, n)
    return jnp.take_along_axis(t, idx, axis=1)


def lut_matmul_ref(x, qp, t):
    """y[p, m] = x[p, n] @ W_hat[m, n]^T, W_hat via LUT gather."""
    n = x.shape[-1]
    w = lut_dequant(qp, t, n)
    return x @ w.T


def lut_matmul_np(x: np.ndarray, q: np.ndarray, t: np.ndarray) -> np.ndarray:
    w = np.take_along_axis(t, q.astype(np.int64), axis=1)
    return x @ w.T


# ---------------------------------------------------------------------------
# Uniform RTN reference (the basic baseline, eq. in §1)
# ---------------------------------------------------------------------------


def rtn_quantize_np(w: np.ndarray, bits: int):
    """Per-channel (row) asymmetric uniform quantization.
    Returns (q codes int, scale [m,1], zero [m,1])."""
    levels = 2**bits - 1
    wmin = w.min(axis=1, keepdims=True)
    wmax = w.max(axis=1, keepdims=True)
    scale = np.maximum((wmax - wmin) / levels, 1e-12)
    zero = np.round(-wmin / scale)
    q = np.clip(np.round(w / scale) + zero, 0, levels)
    return q.astype(np.int32), scale, zero


def rtn_dequant_np(q, scale, zero):
    return (q.astype(np.float32) - zero) * scale


def rtn_codebook_np(w: np.ndarray, bits: int):
    """RTN expressed as a LUT: per-row uniform grid codebook + codes.
    This is also GANQ's T^0 initialization."""
    q, scale, zero = rtn_quantize_np(w, bits)
    k = 2**bits
    grid = np.arange(k, dtype=np.float32)[None, :]
    t = (grid - zero) * scale
    return q, t.astype(np.float32)


# ---------------------------------------------------------------------------
# GANQ reference solver (numpy, float64 internals) — Algorithm 1
# ---------------------------------------------------------------------------


def precondition_np(h: np.ndarray) -> np.ndarray:
    """Adaptive diagonal-dominance preconditioning (paper eq. 23-24)."""
    absrow = np.abs(h).sum(axis=1)
    delta = np.maximum(absrow - 2.0 * np.diag(h), 1e-8)
    return h + np.diag(delta)


def ganq_sstep_np(w, l, t):
    """Back-substitution S-step (paper eq. 22), all rows batched.
    w: [m, n], l: [n, n] lower-triangular, t: [m, K].
    Returns q [m, n] int32."""
    m, n = w.shape
    q = np.zeros((m, n), dtype=np.int32)
    acc = np.zeros((m, n), dtype=w.dtype)  # acc[:, j] accumulates c_j
    for j in range(n - 1, -1, -1):
        e = w[:, j] + acc[:, j] / l[j, j]
        d = np.abs(e[:, None] - t)  # [m, K]
        idx = np.argmin(d, axis=1)
        q[:, j] = idx
        r = w[:, j] - t[np.arange(m), idx]
        # propagate residual to remaining (earlier) columns via row j of L
        acc += r[:, None] * l[j, :][None, :]
    return q


def ganq_tstep_np(w, h, q, t_prev, k: int, eps_rel: float = 1e-6):
    """Closed-form codebook update (paper eq. 7) with regularized solve.
    Empty buckets keep their previous codeword (robustness tweak, noted in
    DESIGN.md)."""
    m, n = w.shape
    g = w @ h  # [m, n]
    t_new = np.empty_like(t_prev)
    for i in range(m):
        onehot = np.zeros((n, k), dtype=w.dtype)
        onehot[np.arange(n), q[i]] = 1.0
        num = g[i] @ onehot  # [K]
        a = onehot.T @ h @ onehot  # [K, K]
        counts = onehot.sum(axis=0)
        eps = eps_rel * max(np.trace(a) / k, 1e-12)
        a_reg = a + eps * np.eye(k, dtype=w.dtype)
        sol = np.linalg.solve(a_reg, num)
        t_new[i] = np.where(counts > 0, sol, t_prev[i])
    return t_new


def layer_error_np(w, w_hat, h):
    """||WX - W_hat X||_F^2 = tr((W - W_hat) H (W - W_hat)^T)."""
    d = w - w_hat
    return float(np.einsum("ij,jk,ik->", d, h, d))


def ganq_reference_np(w, h, bits: int, iters: int = 10):
    """Full GANQ reference: precondition -> cholesky -> K alternating
    iterations. Returns (q, t, per-iteration layer errors)."""
    w = np.asarray(w, dtype=np.float64)
    h = np.asarray(h, dtype=np.float64)
    k = 2**bits
    hp = precondition_np(h)
    l = np.linalg.cholesky(hp)
    _, t = rtn_codebook_np(w.astype(np.float32), bits)
    t = t.astype(np.float64)
    m = w.shape[0]
    errs = []
    q = None
    for _ in range(iters):
        q = ganq_sstep_np(w, l, t)
        t = ganq_tstep_np(w, hp, q, t, k)
        w_hat = t[np.arange(m)[:, None], q]
        errs.append(layer_error_np(w, w_hat, hp))
    # final S-step so Q is consistent with the last T
    q = ganq_sstep_np(w, l, t)
    return q, t, errs


def miqp_bruteforce_np(w, h, bits: int):
    """Exact solution of model (2) by enumeration over S for tiny instances
    (test-only). For each assignment Q the optimal T is the closed form, so
    we enumerate codes jointly. Feasible only for m<=2, n<=6, bits<=2."""
    import itertools

    m, n = w.shape
    k = 2**bits
    hp = precondition_np(np.asarray(h, dtype=np.float64))
    w = np.asarray(w, dtype=np.float64)
    best = []
    for i in range(m):
        best_row = None
        for codes in itertools.product(range(k), repeat=n):
            q = np.array(codes)
            onehot = np.zeros((n, k))
            onehot[np.arange(n), q] = 1.0
            a = onehot.T @ hp @ onehot + 1e-9 * np.eye(k)
            num = (w[i] @ hp) @ onehot
            t = np.linalg.solve(a, num)
            w_hat = t[q]
            d = w[i] - w_hat
            err = float(d @ hp @ d)
            if best_row is None or err < best_row[0]:
                best_row = (err, q.copy(), t.copy())
        best.append(best_row)
    total_err = sum(b[0] for b in best)
    return total_err, best


# ---------------------------------------------------------------------------
# Outlier extraction reference (Algorithm 2)
# ---------------------------------------------------------------------------


def outlier_split_np(w: np.ndarray, ratio: float):
    """Row-wise symmetric-percentile outlier split -> (sparse, dense)."""
    m, n = w.shape
    p = 1.0 - 0.5 * ratio
    upper = min(int(np.floor(n * p)), n - 1)
    lower = int(np.ceil(n * (1.0 - p)))
    ws = np.sort(w, axis=1)
    c_up = ws[:, upper][:, None]
    c_lo = ws[:, lower][:, None]
    mask = (w >= c_up) | (w <= c_lo)
    sparse = np.where(mask, w, 0.0)
    dense = w - sparse
    return sparse, dense
