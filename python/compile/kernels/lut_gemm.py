"""L1 Pallas kernel: LUT-based mixed-precision GEMM (the paper's Fig. 1(a)
right path — dequantization-free inference).

    y[p, m] = x[p, n] @ W_hat[m, n]^T,   W_hat[i, j] = T[i, Q[i, j]]

GPU -> TPU adaptation (DESIGN.md §Hardware-Adaptation): the CUDA kernels the
paper deploys (SqueezeLLM) keep the per-channel codebook in shared memory
and gather with warps. Here the codebook tile T[mt, 2^N] sits in VMEM next
to the activation tile; the packed index tile streams HBM->VMEM via the
BlockSpec grid; the gather is expressed as a one-hot contraction so that the
inner product hits the MXU (bf16-able) instead of scalar lookups:

    W_hat_tile = onehot(Q_tile) @ T_tile^T      (per output-channel row)
    y_tile    += x_tile @ W_hat_tile^T

Lowered with interpret=True (CPU PJRT cannot run Mosaic custom-calls); the
structure (block shapes, VMEM footprint) is what carries to real TPU, and
those estimates live in EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _lut_gemm_kernel(x_ref, qp_ref, t_ref, o_ref, *, block_n: int, kbits: int):
    """One (p-tile, m-tile) grid cell, looping the n dimension in-kernel.

    x_ref:  [bp, n]      activation tile (full reduction dim in VMEM)
    qp_ref: [bm, n//2]   packed nibble codes for this m-tile
    t_ref:  [bm, K]      per-row codebook tile
    o_ref:  [bp, bm]     output tile
    """
    k = 2**kbits
    n2 = qp_ref.shape[1]
    n = n2 * 2
    bm = qp_ref.shape[0]

    qp = qp_ref[...]
    lo = (qp & 0xF).astype(jnp.int32)
    hi = (qp >> 4).astype(jnp.int32)
    idx = jnp.stack([lo, hi], axis=-1).reshape(bm, n)  # [bm, n]

    # one-hot contraction so the dequant itself is an MXU-shaped matmul:
    # W_hat[i, j] = sum_s onehot[i, j, s] * T[i, s]
    onehot = (idx[..., None] == jnp.arange(k)[None, None, :]).astype(
        t_ref.dtype
    )  # [bm, n, K]
    w_hat = jnp.einsum("ijs,is->ij", onehot, t_ref[...])  # [bm, n]

    o_ref[...] = jnp.dot(
        x_ref[...], w_hat.T, preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("kbits", "block_p", "block_m"))
def lut_gemm(x, qp, t, *, kbits: int = 4, block_p: int = 8, block_m: int = 64):
    """Pallas LUT-mpGEMM. x [p, n] f32, qp [m, n//2] u8, t [m, 2^kbits] f32.

    Grid tiles (p, m); the reduction dim n stays resident per tile (our
    layer widths, <= 768 floats/row, fit VMEM comfortably: an (8, 768) x
    tile + (64, 384) u8 + (64, 16) T is ~50 KiB of the ~16 MiB VMEM).
    """
    p, n = x.shape
    m = qp.shape[0]
    bp = min(block_p, p)
    bm = min(block_m, m)
    assert p % bp == 0 and m % bm == 0, (p, m, bp, bm)
    grid = (p // bp, m // bm)
    return pl.pallas_call(
        functools.partial(_lut_gemm_kernel, block_n=n, kbits=kbits),
        out_shape=jax.ShapeDtypeStruct((p, m), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bp, n), lambda i, j: (i, 0)),
            pl.BlockSpec((bm, n // 2), lambda i, j: (j, 0)),
            pl.BlockSpec((bm, 2**kbits), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bp, bm), lambda i, j: (i, j)),
        interpret=True,
    )(x, qp, t)


def vmem_bytes(bp: int, bm: int, n: int, kbits: int) -> int:
    """Static VMEM footprint estimate for one grid cell (f32 activations/out,
    u8 codes). Used by the §Perf block-shape sweep."""
    k = 2**kbits
    return 4 * bp * n + bm * (n // 2) + 4 * bm * k + 4 * bp * bm


def mxu_utilization_estimate(bp: int, bm: int, n: int) -> float:
    """Fraction of MXU (128x128 systolic) lanes covered by the main dot for
    a given block shape — an analytic stand-in for real-TPU profiling."""
    return min(bp / 128.0, 1.0) * min(bm / 128.0, 1.0) * min(n / 128.0, 1.0)
