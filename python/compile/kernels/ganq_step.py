"""L1 Pallas kernel: one back-substitution column step of the GANQ S-step
(paper eq. 18/21/22, Algorithm 1 inner loop).

For column j, all m rows in parallel (the paper's "GPU-adaptive" axis —
rows map to TPU lanes):

    e    = W[:, j] + acc[:, j] / L[j, j]
    idx  = argmin_s |e - T[:, s]|            (codebook lookup, K = 2^N wide)
    r    = W[:, j] - T[gather idx]

The residual propagation acc += r ⊗ L[j, :] stays at L2 (it is a rank-1
update XLA fuses well); the kernel owns the codebook-search hot spot.
Lowered with interpret=True; see DESIGN.md §Hardware-Adaptation.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _step_kernel(w_ref, accj_ref, ljj_ref, t_ref, idx_ref, r_ref):
    """w_ref/accj_ref: [bm] column slices; ljj_ref: [1] scalar diag entry;
    t_ref: [bm, K] codebook; outputs idx [bm] i32, r [bm] f32."""
    e = w_ref[...] + accj_ref[...] / ljj_ref[0]
    d = jnp.abs(e[:, None] - t_ref[...])  # [bm, K]
    idx = jnp.argmin(d, axis=1).astype(jnp.int32)
    idx_ref[...] = idx
    r_ref[...] = w_ref[...] - jnp.take_along_axis(
        t_ref[...], idx[:, None], axis=1
    )[:, 0]


@functools.partial(jax.jit, static_argnames=("block_m",))
def ganq_step(w_col, acc_col, ljj, t, *, block_m: int = 256):
    """One GANQ back-substitution step over all rows.

    w_col [m], acc_col [m], ljj [1], t [m, K] -> (idx [m] i32, r [m] f32).
    """
    m = w_col.shape[0]
    bm = min(block_m, m)
    while m % bm:  # largest divisor of m not exceeding block_m
        bm -= 1
    k = t.shape[1]
    grid = (m // bm,)
    return pl.pallas_call(
        _step_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((m,), jnp.int32),
            jax.ShapeDtypeStruct((m,), jnp.float32),
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm,), lambda i: (i,)),
            pl.BlockSpec((bm,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((bm, k), lambda i: (i, 0)),
        ],
        out_specs=(
            pl.BlockSpec((bm,), lambda i: (i,)),
            pl.BlockSpec((bm,), lambda i: (i,)),
        ),
        interpret=True,
    )(w_col, acc_col, ljj, t)
