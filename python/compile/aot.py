"""AOT compile path: lower every L2 graph (which embed the L1 Pallas
kernels) to HLO *text* artifacts + write the manifest the Rust runtime
loads. Python runs only here — never on the request path.

HLO text, NOT `.serialize()`: jax >= 0.5 emits HloModuleProto with 64-bit
instruction ids which xla_extension 0.5.1 (under the Rust `xla` crate)
rejects; the text parser reassigns ids and round-trips cleanly. See
/opt/xla-example/README.md.

Also emits golden fixtures (artifacts/golden/*.json) used by cargo tests to
pin the Rust-native reimplementations (corpus generator, RTN, GANQ, packing,
model forward) to the Python reference semantics.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

import numpy as np
import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import corpus, model, pretrain, ganq
from .kernels import ref
from .kernels.lut_gemm import lut_gemm

GANQ_ITERS = 10
SERVING_MODELS = ["opt-mini", "opt-small", "opt-med", "opt-longctx"]
# serving batch sizes and chunked-prefill graph sizes. The Rust HloBackend
# buckets each slot's prompt run down to the largest compiled chunk that
# fits and end-pads ragged tails with pos-masked scratch tokens, so this
# small family covers every prompt length.
SERVING_BATCHES = (1, 4)
PREFILL_CHUNKS = (8, 16, 32)
DTYPE_NAME = {np.float32: "f32", np.int32: "i32", np.uint8: "u8"}


def dt(x) -> str:
    return {jnp.float32: "f32", jnp.int32: "i32", jnp.uint8: "u8"}[x]


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def sds(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


class Builder:
    def __init__(self, out_dir: str, force: bool):
        self.out = out_dir
        self.force = force
        self.graphs = {}
        os.makedirs(os.path.join(out_dir, "hlo"), exist_ok=True)
        os.makedirs(os.path.join(out_dir, "golden"), exist_ok=True)

    def lower(self, name, fn, arg_specs, input_names, output_names):
        """arg_specs: [(name, ShapeDtypeStruct)] in call order."""
        path = os.path.join("hlo", name + ".hlo.txt")
        full = os.path.join(self.out, path)
        self.graphs[name] = {
            "path": path,
            "inputs": [
                {
                    "name": nm,
                    "dtype": dt(s.dtype.type)
                    if hasattr(s.dtype, "type")
                    else str(s.dtype),
                    "dims": list(s.shape),
                }
                for nm, s in zip(input_names, arg_specs)
            ],
            "outputs": output_names,
        }
        if os.path.exists(full) and not self.force:
            return
        print(f"  lowering {name} ...", flush=True)
        lowered = jax.jit(fn).lower(*arg_specs)
        text = to_hlo_text(lowered)
        with open(full, "w") as f:
            f.write(text)


def weight_arg_specs(spec):
    out = []
    for name, shape in spec:
        dtype = jnp.uint8 if name.endswith(".qp") else jnp.float32
        out.append((name, sds(shape, dtype)))
    return out


def build_graphs(b: Builder):
    # --- per-config NLL graphs (perplexity eval; weights are args, so one
    # graph serves FP16-baseline and every quant method via reconstruction)
    for mname, cfg in model.CONFIGS.items():
        fn, spec = model.build_nll_fn(cfg, "fp32")
        wspecs = weight_arg_specs(spec)
        args = [("tokens", sds((8, 128), jnp.int32))] + wspecs
        b.lower(
            f"nll_fp32_{mname}",
            fn,
            [s for _, s in args],
            [n for n, _ in args],
            ["nll_sum"],
        )

    # --- serving graphs: decode + prefill, fp32 / lut4 / lut3
    for mname in SERVING_MODELS:
        cfg = model.CONFIGS[mname]
        L, h, ctx = cfg["layers"], cfg["heads"], cfg["ctx"]
        hd = cfg["d"] // h
        for fmt, mode, bits in [
            ("fp32", "fp32", 4),
            ("lut4", "lut", 4),
            ("lut3", "lut", 3),
        ]:
            fn_d, spec = model.build_decode_fn(cfg, mode, bits)
            fn_p, _ = model.build_prefill_fn(cfg, mode, bits)
            wspecs = weight_arg_specs(spec)
            for bsz in SERVING_BATCHES:
                cache = sds((L, bsz, h, ctx, hd))
                args = [
                    ("tok", sds((bsz,), jnp.int32)),
                    ("pos", sds((bsz,), jnp.int32)),
                    ("kcache", cache),
                    ("vcache", cache),
                ] + wspecs
                b.lower(
                    f"decode_{fmt}_{mname}_b{bsz}",
                    fn_d,
                    [s for _, s in args],
                    [n for n, _ in args],
                    ["logits", "kcache", "vcache"],
                )
                # positioned chunked-prefill family: advances every slot
                # by a C-token chunk at per-slot positions; `last` picks
                # the in-chunk row whose logits come back (the final real
                # token of a padded tail)
                for c_len in PREFILL_CHUNKS:
                    args = [
                        ("tokens", sds((bsz, c_len), jnp.int32)),
                        ("pos", sds((bsz,), jnp.int32)),
                        ("last", sds((bsz,), jnp.int32)),
                        ("kcache", cache),
                        ("vcache", cache),
                    ] + wspecs
                    b.lower(
                        f"prefill_{fmt}_{mname}_b{bsz}_c{c_len}",
                        fn_p,
                        [s for _, s in args],
                        [n for n, _ in args],
                        ["logits", "kcache", "vcache"],
                    )

    # --- pallas-kernel serving variant (proves the L1 kernel composes into
    # a full serving graph end-to-end through the Rust runtime)
    for mname in ["opt-micro"]:
        cfg = model.CONFIGS[mname]
        L, h, ctx = cfg["layers"], cfg["heads"], cfg["ctx"]
        hd = cfg["d"] // h
        for fmt, mode in [("fp32", "fp32"), ("lut4", "lut"), ("pallas4", "pallas")]:
            fn_d, spec = model.build_decode_fn(cfg, mode, 4)
            wspecs = weight_arg_specs(spec)
            cache = sds((L, 1, h, ctx, hd))
            args = [
                ("tok", sds((1,), jnp.int32)),
                ("pos", sds((1,), jnp.int32)),
                ("kcache", cache),
                ("vcache", cache),
            ] + wspecs
            b.lower(
                f"decode_{fmt}_{mname}_b1",
                fn_d,
                [s for _, s in args],
                [n for n, _ in args],
                ["logits", "kcache", "vcache"],
            )

    # --- GANQ solver graphs per layer shape (Algorithm 1 with the L1
    # back-substitution kernel inside lax.scan)
    shapes = set()
    for cfg in model.CONFIGS.values():
        for _nm, m, n in model.linear_shapes(cfg):
            shapes.add((m, n))
    for m, n in sorted(shapes):
        for bits in (4, 3):
            k = 2**bits
            fn, arg_shapes = ganq.build_ganq_fn(m, n, bits, GANQ_ITERS)
            names = ["w", "l", "t0"]
            b.lower(
                f"ganq{bits}_{m}x{n}",
                fn,
                arg_shapes,
                names,
                ["q", "t", "errs"],
            )

    # --- solver-piece artifacts: S-step (pallas and plain) and T-step in
    # isolation, used by the Rust integration tests to pin each stage of
    # Algorithm 1 against the native implementation
    m, n, k = 64, 64, 16
    b.lower(
        "sstep4_64x64_pallas",
        lambda w, l, t0: (ganq.sstep(w, l, t0, use_pallas=True),),
        [sds((m, n)), sds((n, n)), sds((m, k))],
        ["w", "l", "t0"],
        ["q"],
    )
    b.lower(
        "sstep4_64x64_plain",
        lambda w, l, t0: (ganq.sstep(w, l, t0, use_pallas=False),),
        [sds((m, n)), sds((n, n)), sds((m, k))],
        ["w", "l", "t0"],
        ["q"],
    )
    b.lower(
        "tstep4_64x64",
        lambda w, h, q, tp: (ganq.tstep(w, h, q, tp),),
        [sds((m, n)), sds((n, n)), sds((m, n), jnp.int32), sds((m, k))],
        ["w", "h", "q", "tprev"],
        ["t"],
    )

    # --- standalone LUT-mpGEMM kernel artifacts (kernel-level bench +
    # validation through the Rust runtime)
    for (p, m, n) in [(8, 128, 128), (8, 512, 128), (8, 128, 512)]:
        for bits in (4, 3):
            k = 2**bits

            def f(x, qp, t, _bits=bits):
                return (lut_gemm(x, qp, t, kbits=_bits, block_p=8,
                                 block_m=64),)

            args = [
                ("x", sds((p, n))),
                ("qp", sds((m, n // 2), jnp.uint8)),
                ("t", sds((m, k))),
            ]
            b.lower(
                f"lutgemm{bits}_p{p}_{m}x{n}",
                f,
                [s for _, s in args],
                [n_ for n_, _ in args],
                ["y"],
            )


def build_goldens(out_dir: str, all_params: dict):
    g = os.path.join(out_dir, "golden")
    rng = np.random.RandomState(42)

    # corpus determinism
    cj = {}
    for flavor in corpus.FLAVORS:
        cj[flavor] = corpus.generate(flavor, "train", 512).decode("ascii")
        cj[flavor + "_valid"] = corpus.generate(flavor, "valid", 256).decode(
            "ascii"
        )
    cj["instruct"] = corpus.instruct_text(256).decode("ascii")
    with open(os.path.join(g, "corpus.json"), "w") as f:
        json.dump(cj, f)

    # GANQ fixture (numpy reference; Rust native must match)
    m, n, bits = 8, 16, 3
    w = rng.randn(m, n).astype(np.float32)
    x = rng.randn(n, 48).astype(np.float32)
    h = x @ x.T
    q, t, errs = ref.ganq_reference_np(w, h, bits, iters=6)
    w_hat = np.take_along_axis(t, q, axis=1)
    hp = ref.precondition_np(h.astype(np.float64))
    q_rtn, t_rtn = ref.rtn_codebook_np(w, bits)
    wh_rtn = np.take_along_axis(t_rtn.astype(np.float64), q_rtn, axis=1)
    with open(os.path.join(g, "ganq.json"), "w") as f:
        json.dump(
            {
                "m": m,
                "n": n,
                "bits": bits,
                "iters": 6,
                "w": w.flatten().tolist(),
                "h": h.flatten().tolist(),
                "errs": [float(e) for e in errs],
                "final_err": ref.layer_error_np(
                    w.astype(np.float64), w_hat, hp
                ),
                "rtn_err": ref.layer_error_np(
                    w.astype(np.float64), wh_rtn, hp
                ),
                "w_hat": w_hat.flatten().tolist(),
            },
            f,
        )

    # RTN fixture
    w4 = rng.randn(4, 8).astype(np.float32)
    q4, t4 = ref.rtn_codebook_np(w4, 4)
    with open(os.path.join(g, "rtn.json"), "w") as f:
        json.dump(
            {
                "w": w4.flatten().tolist(),
                "m": 4,
                "n": 8,
                "bits": 4,
                "q": q4.flatten().tolist(),
                "t": t4.flatten().tolist(),
            },
            f,
        )

    # packing fixtures
    qq = rng.randint(0, 16, (3, 10))
    qp = ref.pack_nibbles(qq)
    q3 = rng.randint(0, 8, (3, 11))
    p3 = ref.pack3(q3)
    with open(os.path.join(g, "pack.json"), "w") as f:
        json.dump(
            {
                "q4": qq.flatten().tolist(),
                "q4_m": 3,
                "q4_n": 10,
                "packed4": qp.flatten().tolist(),
                "q3": q3.flatten().tolist(),
                "q3_m": 3,
                "q3_n": 11,
                "packed3": p3.flatten().tolist(),
            },
            f,
        )

    # any-precision nested layout fixture: parent 4-bit codes decomposed
    # into bit-planes + per-width count-weighted merged codebooks — the
    # nested export rust/src/quant/anyprec.rs mirrors (ragged n pins the
    # bitpacked row padding)
    qa = rng.randint(0, 16, (3, 11))
    ta = rng.randn(3, 16).astype(np.float32)
    planes = ref.pack_bitplanes(qa, 4)
    books = ref.anyprec_codebooks_np(ta, qa, 4, [2, 3, 4])
    with open(os.path.join(g, "anyprec.json"), "w") as f:
        json.dump(
            {
                "m": 3,
                "n": 11,
                "bits": 4,
                "widths": [2, 3, 4],
                "q": qa.flatten().tolist(),
                "t": ta.flatten().tolist(),
                "planes": [p.flatten().tolist() for p in planes],
                "codebooks": {
                    str(w): b.flatten().tolist() for w, b in books.items()
                },
            },
            f,
        )

    # outlier split fixture
    wo = rng.randn(4, 32).astype(np.float32)
    sp, dn = ref.outlier_split_np(wo, 0.125)
    with open(os.path.join(g, "outlier.json"), "w") as f:
        json.dump(
            {
                "w": wo.flatten().tolist(),
                "m": 4,
                "n": 32,
                "ratio": 0.125,
                "sparse": sp.flatten().tolist(),
                "dense": dn.flatten().tolist(),
            },
            f,
        )

    # trained-model forward fixture: logits at last position + nll, used to
    # pin the Rust native forward AND the HLO execution path
    mname = "opt-micro"
    cfg = model.CONFIGS[mname]
    params = {k: jnp.array(v) for k, v in all_params[mname].items()}
    toks = np.frombuffer(
        corpus.generate("wiki2s", "valid", 16), dtype=np.uint8
    ).astype(np.int32)[None, :]
    logits, _, _ = model.fwd(params, toks, cfg)
    nll = model.nll_sum(params, toks, cfg)
    with open(os.path.join(g, "fwd.json"), "w") as f:
        json.dump(
            {
                "model": mname,
                "tokens": toks.flatten().tolist(),
                "logits_last": np.asarray(logits[0, -1]).tolist(),
                "nll_sum": float(nll),
            },
            f,
        )


def build_manifest(b: Builder, out_dir: str):
    models = {}
    for mname in list(model.CONFIGS) + list(model.INSTRUCT_VARIANTS):
        cfg = model.config_for(mname)
        base = model.INSTRUCT_VARIANTS.get(mname, mname)
        models[mname] = {
            "config": {k: int(v) for k, v in cfg.items()},
            "base_config": base,
            "weights": f"weights/{mname}/weights.bin",
            "weights_index": f"weights/{mname}/weights.json",
            "params": [
                {"name": nm, "shape": list(sh)}
                for nm, sh in model.param_spec(cfg)
            ],
            "linears": [
                {"name": nm, "m": m, "n": n}
                for nm, m, n in model.linear_shapes(cfg)
            ],
        }
    manifest = {
        "version": 1,
        "ganq_iters": GANQ_ITERS,
        "models": models,
        "graphs": b.graphs,
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--skip-train", action="store_true")
    args = ap.parse_args()
    out = os.path.abspath(args.out)
    os.makedirs(out, exist_ok=True)

    print("== pretraining model family (cached if present) ==", flush=True)
    all_params = pretrain.ensure_all(out)

    print("== lowering graphs ==", flush=True)
    b = Builder(out, args.force)
    build_graphs(b)

    print("== goldens + manifest ==", flush=True)
    build_goldens(out, all_params)
    build_manifest(b, out)
    print(f"artifacts complete: {out}")


if __name__ == "__main__":
    main()
