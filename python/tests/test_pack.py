"""Code-packing roundtrips (nibble container + dense 3-bit), hypothesis."""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import ref


@settings(max_examples=30, deadline=None)
@given(
    m=st.integers(1, 20),
    n2=st.integers(1, 40),
    bits=st.sampled_from([3, 4]),
    seed=st.integers(0, 2**31 - 1),
)
def test_nibble_roundtrip(m, n2, bits, seed):
    n = 2 * n2
    q = np.random.RandomState(seed).randint(0, 2**bits, (m, n))
    qp = ref.pack_nibbles(q)
    assert qp.shape == (m, n // 2)
    back = ref.unpack_nibbles_np(qp, n)
    assert (back == q).all()


@settings(max_examples=30, deadline=None)
@given(m=st.integers(1, 10), n=st.integers(1, 50), seed=st.integers(0, 2**31 - 1))
def test_pack3_roundtrip(m, n, seed):
    q = np.random.RandomState(seed).randint(0, 8, (m, n))
    qp = ref.pack3(q)
    assert qp.shape[1] == (n + 7) // 8 * 3
    back = ref.unpack3(qp, n)
    assert (back == q).all()


@settings(max_examples=30, deadline=None)
@given(
    m=st.integers(1, 10),
    n=st.integers(1, 50),
    bits=st.sampled_from([2, 3, 4]),
    seed=st.integers(0, 2**31 - 1),
)
def test_bitplane_roundtrip_and_slices(m, n, bits, seed):
    """Planes round-trip the parent codes, and every narrower slice is
    exactly the top-bits shift — incl. ragged n (bitpacked row padding)."""
    q = np.random.RandomState(seed).randint(0, 2**bits, (m, n))
    planes = ref.pack_bitplanes(q, bits)
    assert len(planes) == bits
    assert all(p.shape == (m, (n + 7) // 8) for p in planes)
    assert (ref.unpack_bitplanes(planes, n) == q).all()
    for w in range(1, bits + 1):
        back = ref.unpack_bitplanes(planes, n, w)
        assert (back == (q >> (bits - w))).all(), f"width {w}"


def test_anyprec_merge_is_count_weighted_bucket_mean():
    """Merged codeword = count-weighted mean of its two children; empty
    pairs fall back to the midpoint."""
    t = np.array([[0.0, 1.0, 10.0, 20.0]], dtype=np.float64)
    # codes at width 2: three 0s, one 1, zero 2s/3s
    q = np.array([[0, 0, 0, 1]])
    out = ref.anyprec_merge_codebook_np(t, q)
    assert out.shape == (1, 2)
    assert np.isclose(out[0, 0], (3 * 0.0 + 1 * 1.0) / 4)
    assert np.isclose(out[0, 1], 0.5 * (10.0 + 20.0))  # empty pair


def test_anyprec_codebooks_nest_to_every_width():
    """The seedless derivation yields one codebook per width whose w-bit
    reconstruction is the bucket mean of the parent dequant (the
    identity-Hessian optimum the Rust nest() path pins)."""
    rng = np.random.RandomState(3)
    m, n, bits = 4, 64, 4
    q = rng.randint(0, 2**bits, (m, n))
    t = rng.randn(m, 2**bits).astype(np.float32)
    books = ref.anyprec_codebooks_np(t, q, bits, [2, 3, 4])
    assert sorted(books) == [2, 3, 4]
    assert (books[4] == t).all()
    w_parent = np.take_along_axis(t, q, axis=1)
    for w in (2, 3):
        qw = q >> (bits - w)
        assert books[w].shape == (m, 2**w)
        # each occupied bucket's codeword is the mean of the parent
        # dequant values it absorbed
        for i in range(m):
            for c in range(2**w):
                mask = qw[i] == c
                if mask.any():
                    assert np.isclose(
                        books[w][i, c],
                        w_parent[i, mask].mean(),
                        atol=1e-5,
                    ), (w, i, c)


def test_nibble_matches_jnp_unpack():
    import jax.numpy as jnp

    q = np.random.RandomState(0).randint(0, 16, (6, 12))
    qp = ref.pack_nibbles(q)
    out = np.array(ref.unpack_nibbles(jnp.array(qp), 12))
    assert (out == q).all()


def test_storage_ratio_table1():
    """Paper Table 1: LUT-based 4-bit storage vs FP16, per-channel.
    theory: (0.5*m*n + 32*m) / (2*m*n)."""
    for mn in (2048, 4096, 8192):
        lut = 0.5 * mn * mn + 32 * mn
        full = 2.0 * mn * mn
        ratio = lut / full
        assert 0.25 < ratio < 0.26
