"""Code-packing roundtrips (nibble container + dense 3-bit), hypothesis."""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import ref


@settings(max_examples=30, deadline=None)
@given(
    m=st.integers(1, 20),
    n2=st.integers(1, 40),
    bits=st.sampled_from([3, 4]),
    seed=st.integers(0, 2**31 - 1),
)
def test_nibble_roundtrip(m, n2, bits, seed):
    n = 2 * n2
    q = np.random.RandomState(seed).randint(0, 2**bits, (m, n))
    qp = ref.pack_nibbles(q)
    assert qp.shape == (m, n // 2)
    back = ref.unpack_nibbles_np(qp, n)
    assert (back == q).all()


@settings(max_examples=30, deadline=None)
@given(m=st.integers(1, 10), n=st.integers(1, 50), seed=st.integers(0, 2**31 - 1))
def test_pack3_roundtrip(m, n, seed):
    q = np.random.RandomState(seed).randint(0, 8, (m, n))
    qp = ref.pack3(q)
    assert qp.shape[1] == (n + 7) // 8 * 3
    back = ref.unpack3(qp, n)
    assert (back == q).all()


def test_nibble_matches_jnp_unpack():
    import jax.numpy as jnp

    q = np.random.RandomState(0).randint(0, 16, (6, 12))
    qp = ref.pack_nibbles(q)
    out = np.array(ref.unpack_nibbles(jnp.array(qp), 12))
    assert (out == q).all()


def test_storage_ratio_table1():
    """Paper Table 1: LUT-based 4-bit storage vs FP16, per-channel.
    theory: (0.5*m*n + 32*m) / (2*m*n)."""
    for mn in (2048, 4096, 8192):
        lut = 0.5 * mn * mn + 32 * mn
        full = 2.0 * mn * mn
        ratio = lut / full
        assert 0.25 < ratio < 0.26
