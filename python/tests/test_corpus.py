"""Synthetic corpus generator invariants (the Rust port is additionally
pinned to these bytes via artifacts/golden/corpus.json)."""

import numpy as np

from compile import corpus


def test_deterministic():
    a = corpus.generate("wiki2s", "train", 1000)
    b = corpus.generate("wiki2s", "train", 1000)
    assert a == b


def test_prefix_stable():
    a = corpus.generate("c4s", "train", 400)
    b = corpus.generate("c4s", "train", 800)
    assert b[:400] == a


def test_splits_differ():
    assert corpus.generate("wiki2s", "train", 500) != corpus.generate(
        "wiki2s", "valid", 500
    )


def test_flavors_differ():
    outs = {f: corpus.generate(f, "train", 500) for f in corpus.FLAVORS}
    vals = list(outs.values())
    assert len({v for v in vals}) == 3


def test_ascii_printable():
    text = corpus.generate("ptbs", "train", 2000)
    allowed = set(b"abcdefghijklmnopqrstuvwxyz ,.")
    assert set(text) <= allowed


def test_zipfian_head_heavy():
    """The most frequent word should dominate — that's the non-uniformity
    the language model learns."""
    text = corpus.generate("wiki2s", "train", 60_000).decode()
    words = text.replace(",", "").replace(".", "").split()
    from collections import Counter

    c = Counter(words)
    top = c.most_common(10)
    assert top[0][1] > 5 * top[9][1] / 2  # clearly decaying


def test_bigram_structure_present():
    """The deterministic chain must make some bigram far more likely than
    independence predicts; a trained LM exploits exactly this."""
    text = corpus.generate("wiki2s", "train", 120_000).decode()
    words = text.replace(",", "").replace(".", "").split()
    from collections import Counter

    uni = Counter(words)
    bi = Counter(zip(words, words[1:]))
    (w1, w2), cnt = bi.most_common(1)[0]
    n = len(words)
    p_joint = cnt / n
    p_ind = (uni[w1] / n) * (uni[w2] / n)
    assert p_joint > 3 * p_ind


def test_instruct_text_wellformed():
    text = corpus.instruct_text(5000).decode()
    assert "=" in text and "?" in text
    # every arithmetic statement is actually correct
    for frag in text.split(". "):
        if "+" in frag and "=" in frag and ";" not in frag:
            try:
                lhs, rhs = frag.split("=")
                a, b = lhs.split("+")
                assert int(a) + int(b) == int(rhs)
            except ValueError:
                pass  # clipped fragment at the end
