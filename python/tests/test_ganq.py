"""GANQ solver properties: jnp graph == numpy reference, pallas == jnp,
error monotonicity, dominance over RTN, near-optimality vs exact MIQP."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile import ganq
from compile.kernels import ref


def make_problem(m, n, p, seed):
    rng = np.random.RandomState(seed)
    w = rng.randn(m, n).astype(np.float32)
    x = rng.randn(n, p).astype(np.float32)
    h = (x @ x.T).astype(np.float32)
    hp = ref.precondition_np(h.astype(np.float64))
    l = np.linalg.cholesky(hp).astype(np.float32)
    return w, h, hp, l


@settings(max_examples=8, deadline=None)
@given(
    m=st.sampled_from([4, 16, 32]),
    n=st.sampled_from([8, 24]),
    bits=st.sampled_from([3, 4]),
    seed=st.integers(0, 10_000),
)
def test_jnp_solver_matches_numpy_reference(m, n, bits, seed):
    w, h, hp, l = make_problem(m, n, 3 * n, seed)
    _, t0 = ref.rtn_codebook_np(w, bits)
    q, t, errs = jax.jit(
        lambda w, l, t0: ganq.ganq_solve(w, l, t0, 4, use_pallas=False)
    )(w, l, t0)
    _, _, errs_ref = ref.ganq_reference_np(w, h, bits, iters=4)
    np.testing.assert_allclose(
        np.array(errs), np.array(errs_ref), rtol=2e-3, atol=1e-3
    )


def test_pallas_path_equals_jnp_path():
    w, h, hp, l = make_problem(256, 24, 64, 7)
    _, t0 = ref.rtn_codebook_np(w, 3)
    q1, t1, e1 = jax.jit(
        lambda w, l, t0: ganq.ganq_solve(w, l, t0, 3, use_pallas=True)
    )(w, l, t0)
    q2, t2, e2 = jax.jit(
        lambda w, l, t0: ganq.ganq_solve(w, l, t0, 3, use_pallas=False)
    )(w, l, t0)
    assert (np.array(q1) == np.array(q2)).all()
    np.testing.assert_allclose(np.array(t1), np.array(t2), atol=1e-5)


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 10_000), bits=st.sampled_from([3, 4]))
def test_error_monotone_nonincreasing(seed, bits):
    w, h, hp, l = make_problem(16, 16, 48, seed)
    _, t0 = ref.rtn_codebook_np(w, bits)
    _, _, errs = jax.jit(
        lambda w, l, t0: ganq.ganq_solve(w, l, t0, 6, use_pallas=False)
    )(w, l, t0)
    errs = np.array(errs)
    assert (np.diff(errs) <= np.abs(errs[:-1]) * 1e-4 + 1e-5).all(), errs


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 10_000), bits=st.sampled_from([3, 4]))
def test_ganq_beats_rtn(seed, bits):
    """The paper's core claim at layer level: GANQ layer error < RTN."""
    w, h, hp, l = make_problem(24, 32, 64, seed)
    _, t0 = ref.rtn_codebook_np(w, bits)
    q, t, _ = jax.jit(
        lambda w, l, t0: ganq.ganq_solve(w, l, t0, 8, use_pallas=False)
    )(w, l, t0)
    w_hat = np.take_along_axis(np.array(t), np.array(q), axis=1)
    e_ganq = ref.layer_error_np(w.astype(np.float64), w_hat, hp)
    q_rtn, t_rtn = ref.rtn_codebook_np(w, bits)
    wh = np.take_along_axis(t_rtn.astype(np.float64), q_rtn, axis=1)
    e_rtn = ref.layer_error_np(w.astype(np.float64), wh, hp)
    assert e_ganq < e_rtn


def test_vs_exact_miqp_bound():
    """On enumerable instances the brute-force MIQP optimum must lower-bound
    GANQ (sanity that the solver and the model agree), and the alternating
    heuristic should stay within a moderate factor of it while beating RTN.
    The paper (§3.2) derives a *sub-optimal* solution; tiny adversarial n=6
    instances are the worst case for alternating minimization, hence the
    generous factor here."""
    rng = np.random.RandomState(3)
    w = rng.randn(2, 6).astype(np.float32)
    x = rng.randn(6, 12).astype(np.float32)
    h = x @ x.T
    hp = ref.precondition_np(h.astype(np.float64))
    opt_err, _ = ref.miqp_bruteforce_np(w, h, bits=2)
    q, t, errs = ref.ganq_reference_np(w, h, bits=2, iters=12)
    w_hat = np.take_along_axis(t, q, axis=1)
    e = ref.layer_error_np(w.astype(np.float64), w_hat, hp)
    assert e >= opt_err - 1e-9, "brute force must lower-bound GANQ"
    assert e <= 20.0 * opt_err + 1e-6, (e, opt_err)
    q_rtn, t_rtn = ref.rtn_codebook_np(w, 2)
    wh_rtn = np.take_along_axis(t_rtn.astype(np.float64), q_rtn, axis=1)
    e_rtn = ref.layer_error_np(w.astype(np.float64), wh_rtn, hp)
    assert e <= e_rtn + 1e-9


def test_chol_solve_small():
    rng = np.random.RandomState(0)
    for k in (8, 16):
        b = rng.randn(5, k).astype(np.float32)
        r = rng.randn(5, k, k).astype(np.float32)
        a = np.einsum("mij,mkj->mik", r, r) + 0.1 * np.eye(k, dtype=np.float32)
        x = np.array(jax.jit(ganq.chol_solve_small)(a, b))
        np.testing.assert_allclose(
            np.einsum("mij,mj->mi", a, x), b, rtol=1e-3, atol=1e-3
        )


def test_precondition_makes_cholesky_safe():
    """fc2-style degenerate H (rank-deficient) must factor after eq. 23-24."""
    rng = np.random.RandomState(1)
    x = rng.randn(3, 40).astype(np.float64)  # n=20 but rank 3
    xfull = np.zeros((20, 40))
    xfull[:3] = x
    h = xfull @ xfull.T  # singular
    hp = ref.precondition_np(h)
    l = np.linalg.cholesky(hp)  # must not raise
    assert np.isfinite(l).all()


def test_empty_bucket_keeps_previous_codeword():
    w = np.full((1, 8), 0.5, np.float32)
    h = np.eye(8, dtype=np.float32)
    q = np.zeros((1, 8), np.int32)  # all mass in bucket 0
    t_prev = np.arange(4, dtype=np.float32)[None] * 10
    t_new = ref.ganq_tstep_np(
        w.astype(np.float64), h.astype(np.float64), q,
        t_prev.astype(np.float64), 4,
    )
    # buckets 1..3 untouched
    np.testing.assert_allclose(t_new[0, 1:], t_prev[0, 1:])
    np.testing.assert_allclose(t_new[0, 0], 0.5, atol=1e-6)


def test_outlier_split_reconstructs_and_is_sparse():
    rng = np.random.RandomState(9)
    w = rng.randn(16, 64).astype(np.float32)
    sp, dn = ref.outlier_split_np(w, 0.1)
    np.testing.assert_allclose(sp + dn, w, atol=0)
    frac = (sp != 0).mean()
    assert frac <= 0.2
    # dense range shrank
    assert np.abs(dn).max() < np.abs(w).max()
