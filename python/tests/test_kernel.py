"""Pallas kernels vs pure-jnp/numpy oracles — the core L1 correctness
signal. Hypothesis sweeps shapes and bit-widths."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.lut_gemm import lut_gemm, vmem_bytes, mxu_utilization_estimate
from compile.kernels.ganq_step import ganq_step


@settings(max_examples=12, deadline=None)
@given(
    p=st.sampled_from([1, 4, 8, 16]),
    mt=st.sampled_from([16, 64, 128]),
    nt=st.sampled_from([8, 32, 64]),
    bits=st.sampled_from([3, 4]),
    seed=st.integers(0, 2**31 - 1),
)
def test_lut_gemm_matches_ref(p, mt, nt, bits, seed):
    rng = np.random.RandomState(seed)
    k = 2**bits
    q = rng.randint(0, k, (mt, nt))
    t = rng.randn(mt, k).astype(np.float32)
    x = rng.randn(p, nt).astype(np.float32)
    qp = ref.pack_nibbles(q)
    y_ref = ref.lut_matmul_np(x, q, t)
    bp = p if p < 8 else 8
    bm = mt if mt < 64 else 64
    y = lut_gemm(
        jnp.array(x), jnp.array(qp), jnp.array(t),
        kbits=bits, block_p=bp, block_m=bm,
    )
    np.testing.assert_allclose(np.array(y), y_ref, rtol=1e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(
    m=st.sampled_from([32, 128, 256]),
    bits=st.sampled_from([3, 4]),
    seed=st.integers(0, 2**31 - 1),
)
def test_ganq_step_matches_ref(m, bits, seed):
    rng = np.random.RandomState(seed)
    k = 2**bits
    w = rng.randn(m).astype(np.float32)
    acc = rng.randn(m).astype(np.float32)
    ljj = np.abs(rng.randn(1)).astype(np.float32) + 0.5
    t = rng.randn(m, k).astype(np.float32)
    idx, r = ganq_step(
        jnp.array(w), jnp.array(acc), jnp.array(ljj), jnp.array(t),
        block_m=min(m, 256),
    )
    e = w + acc / ljj[0]
    idx_ref = np.argmin(np.abs(e[:, None] - t), axis=1)
    # ties are astronomically unlikely with gaussian data
    assert (np.array(idx) == idx_ref).all()
    r_ref = w - t[np.arange(m), idx_ref]
    np.testing.assert_allclose(np.array(r), r_ref, atol=1e-6)


def test_lut_gemm_rejects_misaligned():
    with pytest.raises(AssertionError):
        lut_gemm(
            jnp.zeros((7, 8)), jnp.zeros((16, 4), jnp.uint8),
            jnp.zeros((16, 16)), kbits=4, block_p=4, block_m=16,
        )


def test_vmem_estimate_within_budget():
    # DESIGN.md: default tile must sit far below the ~16 MiB VMEM budget
    assert vmem_bytes(8, 64, 768, 4) < 1 << 20
    assert 0.0 < mxu_utilization_estimate(8, 64, 768) <= 1.0


def test_lut_gemm_zero_codebook_gives_zero():
    x = np.random.RandomState(0).randn(8, 32).astype(np.float32)
    qp = np.random.RandomState(1).randint(0, 255, (64, 16)).astype(np.uint8)
    t = np.zeros((64, 16), np.float32)
    y = lut_gemm(jnp.array(x), jnp.array(qp), jnp.array(t))
    assert np.abs(np.array(y)).max() == 0.0
