"""L2 model graph checks: shapes, decode/prefill/fwd consistency, LUT mode
equivalence with dequantized FP32, and graph-builder arg plumbing."""

import numpy as np
import jax.numpy as jnp
import pytest

from compile import model
from compile.kernels import ref


@pytest.fixture(scope="module")
def micro():
    cfg = model.CONFIGS["opt-micro"]
    params = model.init_params(0, cfg)
    return cfg, params


def quantize_params(params, cfg, bits):
    """RTN-as-LUT quantization of every quantizable linear -> lut params."""
    out = dict(params)
    for name, m, n in model.linear_shapes(cfg):
        q, t = ref.rtn_codebook_np(params[name], bits)
        out[name + ".qp"] = ref.pack_nibbles(q)
        out[name + ".t"] = t
        del out[name]
    return out


def test_fwd_shapes(micro):
    cfg, params = micro
    toks = np.zeros((2, 10), np.int32)
    logits, kcs, vcs = model.fwd(params, toks, cfg)
    assert logits.shape == (2, 10, cfg["vocab"])
    assert len(kcs) == cfg["layers"]
    assert kcs[0].shape == (2, cfg["heads"], 10, cfg["d"] // cfg["heads"])


def test_decode_matches_fwd(micro):
    cfg, params = micro
    rng = np.random.RandomState(0)
    toks = rng.randint(0, 256, (2, 12)).astype(np.int32)
    lg, kc, vc = model.prefill(params, toks, cfg)
    logits_full, _, _ = model.fwd(params, toks, cfg)
    np.testing.assert_allclose(
        np.array(lg), np.array(logits_full[:, -1]), atol=1e-5
    )
    nxt = np.argmax(np.array(lg), -1).astype(np.int32)
    pos = np.array([12, 12], np.int32)
    lg2, _, _ = model.decode_step(params, nxt, pos, kc, vc, cfg)
    toks13 = np.concatenate([toks, nxt[:, None]], 1).astype(np.int32)
    logits13, _, _ = model.fwd(params, toks13, cfg)
    np.testing.assert_allclose(
        np.array(lg2), np.array(logits13[:, -1]), atol=1e-4
    )


def test_decode_per_slot_positions(micro):
    """Slots at different positions must behave like independent sequences."""
    cfg, params = micro
    rng = np.random.RandomState(1)
    t_a = rng.randint(0, 256, (1, 8)).astype(np.int32)
    t_b = rng.randint(0, 256, (1, 5)).astype(np.int32)
    _, kc_a, vc_a = model.prefill(params, t_a, cfg)
    _, kc_b, vc_b = model.prefill(params, t_b, cfg)
    # batched caches
    kc = np.concatenate([np.array(kc_a), np.array(kc_b)], axis=1)
    vc = np.concatenate([np.array(vc_a), np.array(vc_b)], axis=1)
    tok = np.array([65, 66], np.int32)
    pos = np.array([8, 5], np.int32)
    lg, _, _ = model.decode_step(params, tok, pos, kc, vc, cfg)
    # singletons
    lg_a, _, _ = model.decode_step(
        params, tok[:1], pos[:1], np.array(kc_a), np.array(vc_a), cfg
    )
    lg_b, _, _ = model.decode_step(
        params, tok[1:], pos[1:], np.array(kc_b), np.array(vc_b), cfg
    )
    np.testing.assert_allclose(np.array(lg[0]), np.array(lg_a[0]), atol=1e-4)
    np.testing.assert_allclose(np.array(lg[1]), np.array(lg_b[0]), atol=1e-4)


@pytest.mark.parametrize("bits", [4, 3])
def test_lut_mode_equals_dequantized_fp32(micro, bits):
    """Running the LUT graph on (Q,T) must equal the FP32 graph on the
    reconstructed W-hat — the serving path computes exactly W_hat X."""
    cfg, params = micro
    qparams = quantize_params(params, cfg, bits)
    deq = dict(params)
    for name, m, n in model.linear_shapes(cfg):
        idx = ref.unpack_nibbles_np(qparams[name + ".qp"], n)
        deq[name] = np.take_along_axis(qparams[name + ".t"], idx, axis=1)
    toks = np.random.RandomState(2).randint(0, 256, (1, 9)).astype(np.int32)
    lg_lut, _, _ = model.fwd(qparams, toks, cfg, mode="lut")
    lg_fp, _, _ = model.fwd(deq, toks, cfg, mode="fp32")
    np.testing.assert_allclose(
        np.array(lg_lut), np.array(lg_fp), rtol=1e-4, atol=1e-4
    )


def test_pallas_mode_equals_lut_mode(micro):
    cfg, params = micro
    qparams = quantize_params(params, cfg, 4)
    tok = np.array([65], np.int32)
    pos = np.array([0], np.int32)
    L, h = cfg["layers"], cfg["heads"]
    hd = cfg["d"] // h
    kc = np.zeros((L, 1, h, cfg["ctx"], hd), np.float32)
    vc = np.zeros_like(kc)
    lg1, _, _ = model.decode_step(qparams, tok, pos, kc, vc, cfg, mode="lut")
    lg2, _, _ = model.decode_step(
        qparams, tok, pos, kc, vc, cfg, mode="pallas"
    )
    np.testing.assert_allclose(np.array(lg1), np.array(lg2), atol=1e-4)


def test_prefill_chunk_matches_whole_prefill(micro):
    """Feeding a prompt as positioned chunks must reproduce the
    whole-sequence prefill: same last-position logits, same cache rows."""
    cfg, params = micro
    rng = np.random.RandomState(4)
    toks = rng.randint(0, 256, (2, 12)).astype(np.int32)
    lg_full, kc_full, vc_full = model.prefill(params, toks, cfg)
    L, h = cfg["layers"], cfg["heads"]
    hd = cfg["d"] // h
    kc = np.zeros((L, 2, h, cfg["ctx"], hd), np.float32)
    vc = np.zeros_like(kc)
    lg = None
    for start in (0, 5):  # ragged chunk split: 5 + 7
        c = (5 if start == 0 else 7)
        chunk = toks[:, start : start + c]
        pos = np.full(2, start, np.int32)
        last = np.full(2, c - 1, np.int32)
        lg, kc, vc = model.prefill_chunk(
            params, chunk, pos, last, kc, vc, cfg
        )
    np.testing.assert_allclose(np.array(lg), np.array(lg_full), atol=1e-5)
    np.testing.assert_allclose(
        np.array(kc)[:, :, :, :12], np.array(kc_full)[:, :, :, :12],
        atol=1e-5,
    )
    np.testing.assert_allclose(
        np.array(vc)[:, :, :, :12], np.array(vc_full)[:, :, :, :12],
        atol=1e-5,
    )


def test_prefill_chunk_padded_tail(micro):
    """End-padding a short run with scratch tokens must not change the
    last real token's logits or the real cache rows, even when the pad
    spills past the context window (pos-masked drop)."""
    cfg, params = micro
    rng = np.random.RandomState(5)
    L, h = cfg["layers"], cfg["heads"]
    hd = cfg["d"] // h
    for start, r, c in [(0, 3, 8), (cfg["ctx"] - 4, 3, 8)]:
        toks_r = rng.randint(0, 256, (1, r)).astype(np.int32)
        zeros = lambda: (
            np.zeros((L, 1, h, cfg["ctx"], hd), np.float32),
            np.zeros((L, 1, h, cfg["ctx"], hd), np.float32),
        )
        pos = np.array([start], np.int32)
        kc0, vc0 = zeros()
        lg_exact, kc_e, _ = model.prefill_chunk(
            params, toks_r, pos, np.array([r - 1], np.int32), kc0, vc0, cfg
        )
        padded = np.zeros((1, c), np.int32)
        padded[0, :r] = toks_r
        kc0, vc0 = zeros()
        lg_pad, kc_p, _ = model.prefill_chunk(
            params, padded, pos, np.array([r - 1], np.int32), kc0, vc0, cfg
        )
        np.testing.assert_array_equal(np.array(lg_exact), np.array(lg_pad))
        np.testing.assert_array_equal(
            np.array(kc_e)[:, :, :, start : start + r],
            np.array(kc_p)[:, :, :, start : start + r],
        )


def test_prefill_chunk_equals_decode_steps(micro):
    """A C-token chunk is exactly C sequential decode steps (same cache
    writes, ~identical logits)."""
    cfg, params = micro
    rng = np.random.RandomState(6)
    toks = rng.randint(0, 256, (1, 6)).astype(np.int32)
    L, h = cfg["layers"], cfg["heads"]
    hd = cfg["d"] // h
    kc = np.zeros((L, 1, h, cfg["ctx"], hd), np.float32)
    vc = np.zeros_like(kc)
    lg_d = None
    for i in range(6):
        lg_d, kc, vc = model.decode_step(
            params, toks[:, i], np.array([i], np.int32), kc, vc, cfg
        )
    kc2 = np.zeros_like(kc)
    vc2 = np.zeros_like(vc)
    lg_c, kc2, vc2 = model.prefill_chunk(
        params, toks, np.array([0], np.int32), np.array([5], np.int32),
        kc2, vc2, cfg,
    )
    np.testing.assert_allclose(np.array(lg_d), np.array(lg_c), atol=1e-4)
    np.testing.assert_allclose(
        np.array(kc)[:, :, :, :6], np.array(kc2)[:, :, :, :6], atol=1e-5
    )


def test_prefill_chunk_lut_mode(micro):
    cfg, params = micro
    qparams = quantize_params(params, cfg, 4)
    rng = np.random.RandomState(8)
    toks = rng.randint(0, 256, (1, 8)).astype(np.int32)
    L, h = cfg["layers"], cfg["heads"]
    hd = cfg["d"] // h
    kc = np.zeros((L, 1, h, cfg["ctx"], hd), np.float32)
    vc = np.zeros_like(kc)
    lg, _, _ = model.prefill_chunk(
        params, toks, np.array([0], np.int32), np.array([7], np.int32),
        kc, vc, cfg,
    )
    deq = dict(params)
    for name, m, n in model.linear_shapes(cfg):
        idx = ref.unpack_nibbles_np(qparams[name + ".qp"], n)
        deq[name] = np.take_along_axis(qparams[name + ".t"], idx, axis=1)
    lg_lut, _, _ = model.prefill_chunk(
        qparams, toks, np.array([0], np.int32), np.array([7], np.int32),
        kc, vc, cfg, mode="lut",
    )
    lg_deq, _, _ = model.prefill_chunk(
        deq, toks, np.array([0], np.int32), np.array([7], np.int32),
        kc, vc, cfg,
    )
    np.testing.assert_allclose(
        np.array(lg_lut), np.array(lg_deq), rtol=1e-4, atol=1e-4
    )
    assert np.isfinite(np.array(lg)).all()


def test_build_prefill_fn_chunked_signature(micro):
    cfg, params = micro
    fn, spec = model.build_prefill_fn(cfg, "fp32")
    L, h = cfg["layers"], cfg["heads"]
    hd = cfg["d"] // h
    kc = np.zeros((L, 1, h, cfg["ctx"], hd), np.float32)
    toks = np.zeros((1, 8), np.int32)
    lg, kc_out, vc_out = fn(
        toks,
        np.zeros(1, np.int32),
        np.full(1, 7, np.int32),
        kc,
        np.zeros_like(kc),
        *model.params_to_list(params, spec),
    )
    assert lg.shape == (1, cfg["vocab"])
    assert kc_out.shape == kc.shape and vc_out.shape == kc.shape


def test_nll_matches_manual(micro):
    cfg, params = micro
    toks = np.random.RandomState(3).randint(0, 256, (2, 7)).astype(np.int32)
    s = float(model.nll_sum(params, toks, cfg))
    logits, _, _ = model.fwd(params, toks, cfg)
    lp = np.array(logits[:, :-1])
    lp = lp - lp.max(-1, keepdims=True)
    lp = lp - np.log(np.exp(lp).sum(-1, keepdims=True))
    manual = -sum(
        lp[b, i, toks[b, i + 1]] for b in range(2) for i in range(6)
    )
    assert abs(s - manual) < 1e-3


def test_param_specs_consistent(micro):
    cfg, _ = micro
    spec = model.param_spec(cfg)
    names = [n for n, _ in spec]
    assert len(names) == len(set(names))
    lspec = model.lut_param_spec(cfg, 4)
    lnames = [n for n, _ in lspec]
    for name, m, n in model.linear_shapes(cfg):
        assert name in names and name not in lnames
        assert name + ".qp" in lnames and name + ".t" in lnames


def test_graph_builders_run(micro):
    cfg, params = micro
    fn, spec = model.build_nll_fn(cfg, "fp32")
    toks = np.zeros((8, 128), np.int32)
    (out,) = fn(toks, *model.params_to_list(params, spec))
    assert np.isfinite(float(out))
