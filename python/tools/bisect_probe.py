import sys, os, json, glob
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
import jax, jax.numpy as jnp, numpy as np
from jax._src.lib import xla_client as xc

for f in glob.glob('/tmp/bisect_*'):
    os.remove(f)

n, m = 16, 8
rng = np.random.RandomState(0)
W = rng.randn(m, n).astype(np.float32)
L = np.tril(rng.randn(n, n).astype(np.float32)) + 3*np.eye(n, dtype=np.float32)
T = rng.randn(m, 16).astype(np.float32)

def scan_over(body, outshape):
    def f(w, l, t):
        js = jnp.arange(n - 1, -1, -1)
        acc, ys = jax.lax.scan(lambda a, j: body(w, l, t, a, j), jnp.zeros((m, n), jnp.float32), js)
        # keep all params live so jit doesn't prune unused args
        ys = ys + 0.0 * (w[0, 0] + l[0, 0] + t[0, 0])
        return (acc, ys)
    return f

# v1: xs consumption only (carry += j broadcast)
def v1(w, l, t, acc, j):
    acc = acc + j.astype(jnp.float32)
    return acc, jnp.float32(0)

# v2: dynamic_slice row of l by j
def v2(w, l, t, acc, j):
    lrow = jax.lax.dynamic_slice(l, (j, 0), (1, n))[0]
    acc = acc + lrow[None, :]
    return acc, lrow[0]

# v3: gather w[:, j]
def v3(w, l, t, acc, j):
    wj = w[:, j]
    acc = acc + wj[:, None]
    return acc, wj[0]

# v4: gather from CARRY acc[:, j]
def v4(w, l, t, acc, j):
    aj = acc[:, j]
    acc = acc + 1.0 + aj[:, None] * 0.01
    return acc, aj[0]

# v5: argmin over codebook
def v5(w, l, t, acc, j):
    e = w[:, j]
    idx = jnp.argmin(jnp.abs(e[:, None] - t), axis=1).astype(jnp.int32)
    acc = acc + idx.astype(jnp.float32)[:, None] * 0.1
    return acc, idx[0]

# v6: take_along_axis per-row gather
def v6(w, l, t, acc, j):
    e = w[:, j]
    idx = jnp.argmin(jnp.abs(e[:, None] - t), axis=1).astype(jnp.int32)
    tv = jnp.take_along_axis(t, idx[:, None], axis=1)[:, 0]
    acc = acc + tv[:, None] * 0.1
    return acc, tv[0]

# v7: full body (= real sstep body)
def v7(w, l, t, acc, j):
    ljj = jax.lax.dynamic_slice(jnp.diagonal(l), (j,), (1,))
    wj = w[:, j]
    accj = acc[:, j]
    e = wj + accj / ljj[0]
    idx = jnp.argmin(jnp.abs(e[:, None] - t), axis=1).astype(jnp.int32)
    r = wj - jnp.take_along_axis(t, idx[:, None], axis=1)[:, 0]
    lrow = jax.lax.dynamic_slice(l, (j, 0), (1, n))[0]
    acc = acc + r[:, None] * lrow[None, :]
    return acc, r[0]

for name, body in [('v1',v1),('v2',v2),('v3',v3),('v4',v4),('v5',v5),('v6',v6),('v7',v7)]:
    f = scan_over(body, None)
    acc, ys = f(jnp.array(W), jnp.array(L), jnp.array(T))
    lowered = jax.jit(f).lower(*[jax.ShapeDtypeStruct(a.shape, a.dtype) for a in (W, L, T)])
    comp = xc._xla.mlir.mlir_module_to_xla_computation(str(lowered.compiler_ir('stablehlo')), use_tuple_args=False, return_tuple=True)
    open(f'/tmp/bisect_{name}.hlo.txt','w').write(comp.as_hlo_text())
    json.dump({'m':m,'n':n,
      'w':W.flatten().tolist(),'l':L.flatten().tolist(),'t':T.flatten().tolist(),
      'acc':np.array(acc).flatten().tolist(),
      'ys':np.array(ys).astype(np.float32).flatten().tolist()},
      open(f'/tmp/bisect_{name}.json','w'))
    print('wrote', name)

# --- v8/v9/v10: the candidate FIXED formulation ---
def gen_fixed(name, use_tl):
    def f(w, l, t):
        wcols = w.T              # [n, m]
        ldiag = jnp.diagonal(l)  # [n]
        def body(acc, xs):
            wj, lrow, ljj, j = xs
            accj = jnp.take_along_axis(acc, jnp.full((m, 1), j, jnp.int32), axis=1)[:, 0]
            e = wj + accj / ljj
            idx = jnp.argmin(jnp.abs(e[:, None] - t), axis=1).astype(jnp.int32)
            if use_tl:
                tv = jnp.take_along_axis(t, idx[:, None], axis=1)[:, 0]
            else:
                oh = jax.nn.one_hot(idx, t.shape[1], dtype=w.dtype)
                tv = jnp.sum(oh * t, axis=1)
            r = wj - tv
            acc = acc + r[:, None] * lrow[None, :]
            return acc, idx
        js = jnp.arange(n, dtype=jnp.int32)
        acc, idxs = jax.lax.scan(body, jnp.zeros((m, n), jnp.float32), (wcols, l, ldiag, js), reverse=True)
        ys = idxs.astype(jnp.float32)[:, 0]
        ys = ys + 0.0 * (w[0, 0] + l[0, 0] + t[0, 0])
        return (acc, ys)
    return f

for name, use_tl in [('v8', True), ('v9', False)]:
    f = gen_fixed(name, use_tl)
    acc, ys = f(jnp.array(W), jnp.array(L), jnp.array(T))
    lowered = jax.jit(f).lower(*[jax.ShapeDtypeStruct(a.shape, a.dtype) for a in (W, L, T)])
    comp = xc._xla.mlir.mlir_module_to_xla_computation(str(lowered.compiler_ir('stablehlo')), use_tuple_args=False, return_tuple=True)
    open(f'/tmp/bisect_{name}.hlo.txt','w').write(comp.as_hlo_text())
    json.dump({'m':m,'n':n,
      'w':W.flatten().tolist(),'l':L.flatten().tolist(),'t':T.flatten().tolist(),
      'acc':np.array(acc).flatten().tolist(),
      'ys':np.array(ys).astype(np.float32).flatten().tolist()},
      open(f'/tmp/bisect_{name}.json','w'))
    print('wrote', name)
