import sys, os; sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
import sys, json
import jax, jax.numpy as jnp, numpy as np
from jax._src.lib import xla_client as xc
from compile import ganq
from compile.kernels import ref

m, n, bits = int(sys.argv[1]), int(sys.argv[2]), int(sys.argv[3])
rng = np.random.RandomState(11)
w = rng.randn(m, n).astype(np.float32)
x = rng.randn(n, 2*n+32).astype(np.float32)
h = (x @ x.T)
hp = ref.precondition_np(h.astype(np.float64))
l = np.linalg.cholesky(hp).astype(np.float32)
_, t0 = ref.rtn_codebook_np(w, bits)

def f(w, l, t0):
    return (ganq.sstep(w, l, t0, use_pallas=False),)

q = np.array(f(jnp.array(w), jnp.array(l), jnp.array(t0))[0])
lowered = jax.jit(f).lower(*[jax.ShapeDtypeStruct(a.shape, a.dtype) for a in (w, l, t0)])
comp = xc._xla.mlir.mlir_module_to_xla_computation(str(lowered.compiler_ir('stablehlo')), use_tuple_args=False, return_tuple=True)
open('/tmp/probe.hlo.txt','w').write(comp.as_hlo_text())
json.dump({'m':m,'n':n,'k':2**bits,
  'w':w.flatten().tolist(),'l':l.flatten().tolist(),'t0':t0.flatten().tolist(),
  'q':q.flatten().tolist()}, open('/tmp/probe.json','w'))
print('wrote probe for', m, n, bits)
